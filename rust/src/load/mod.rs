//! Open-loop load harness: million-request arrival streams, sharded
//! dispatch, fixed-memory latency percentiles, SLO/shed accounting.
//!
//! The closed-loop engine ([`crate::engine`]) answers "how fast does
//! the pipeline drain a backlog"; this module answers the production
//! question — "what happens when requests keep arriving at a rate the
//! pipeline does not control". A [`LoadSpec`] names a seeded
//! [`ArrivalProcess`], admission knobs and a thread count; [`run_load`]
//! plays the trace through sharded per-replica admission queues
//! ([`dispatch`]) and folds the outcome into a [`LoadReport`] —
//! throughput, p50/p95/p99/p99.9 from an HDR-style histogram
//! ([`LatencyHistogram`]), shed rate, and deadline-miss accounting.
//! Memory is O(replicas + ring slots + histogram buckets), never
//! O(requests): a million-request Poisson overload runs in a few MB.
//!
//! Three runners, one semantics:
//! * [`run_load`] — sharded threaded harness (SPSC rings + seqlock
//!   telemetry cells, no shared lock on the hot path);
//! * [`run_load_mutexed`] — the same structure behind one global
//!   `Mutex`, kept as the contended baseline for
//!   `benches/perf_serving.rs`;
//! * [`run_load_reference`] — the sequential analytic twin
//!   ([`crate::sim::simulate_open_loop`] calls it).
//!
//! All three agree *exactly* on admitted/shed counts and histograms —
//! `rust/tests/open_loop.rs` pins it. [`sweep_shed_curve`] maps the
//! (arrival rate × replicas) grid to throughput/p99/shed-rate points,
//! the scaling table `BENCH_serving.json` records.

mod arrivals;
mod dispatch;
mod histogram;
pub mod queue;

pub use arrivals::ArrivalProcess;
pub use histogram::LatencyHistogram;
pub use queue::{ClockCell, Polled, ShardQueue};

use crate::engine::{AdmissionPolicy, StageProfile};
use dispatch::{OfferOptions, ReplicaSim};

/// One open-loop experiment: what arrives, how admission treats it,
/// and how the harness runs it.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    pub process: ArrivalProcess,
    pub n_requests: usize,
    pub seed: u64,
    /// Max in-flight requests per replica (clamped to >= 1).
    pub queue_capacity: usize,
    pub admission: AdmissionPolicy,
    /// SLO deadline on arrival-to-completion latency (None = no SLO).
    pub deadline: Option<f64>,
    /// Shed requests whose predicted completion would miss `deadline`.
    pub shed_on_deadline: bool,
    /// Worker threads for the sharded/mutexed runners (clamped to the
    /// replica count; the reference runner ignores it).
    pub threads: usize,
    /// Slots per per-replica admission ring (the backpressure bound).
    pub channel_capacity: usize,
}

impl Default for LoadSpec {
    fn default() -> Self {
        LoadSpec {
            process: ArrivalProcess::Poisson { rate: 100.0 },
            n_requests: 10_000,
            seed: 1,
            queue_capacity: 64,
            admission: AdmissionPolicy::Shed,
            deadline: None,
            shed_on_deadline: false,
            threads: 4,
            channel_capacity: 1024,
        }
    }
}

impl LoadSpec {
    fn offer_options(&self) -> OfferOptions {
        OfferOptions {
            queue_capacity: self.queue_capacity.max(1),
            admission: self.admission,
            deadline: self.deadline,
            shed_on_deadline: self.shed_on_deadline,
        }
    }
}

/// SLO outcome of a run (present when the spec set a deadline).
#[derive(Debug, Clone, Copy)]
pub struct SloReport {
    pub deadline: f64,
    /// Admitted requests that finished after the deadline.
    pub misses: u64,
    /// `misses / admitted` (0.0 when nothing was admitted).
    pub miss_rate: f64,
}

/// Per-replica slice of a run.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaLoad {
    pub replica: usize,
    pub admitted: u64,
    pub shed: u64,
    /// Latest completion on this replica (virtual seconds).
    pub horizon: f64,
}

/// Everything a load run reports. All statistics are defined (0.0, not
/// NaN) for the zero-admitted / 100%-shed case — pinned by tests.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// Requests in the arrival trace.
    pub offered: u64,
    pub admitted: u64,
    pub shed_queue: u64,
    pub shed_deadline: u64,
    /// `(shed_queue + shed_deadline) / offered`.
    pub shed_rate: f64,
    /// Offered arrival rate over the trace span (requests/sec).
    pub offered_rate: f64,
    /// Last completion minus first arrival (virtual seconds).
    pub makespan: f64,
    /// `admitted / makespan` (virtual requests/sec).
    pub throughput: f64,
    pub mean_latency: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub p999: f64,
    pub slo: Option<SloReport>,
    pub per_replica: Vec<ReplicaLoad>,
    /// Merged per-request latency histogram (fixed memory).
    pub histogram: LatencyHistogram,
    /// Host wall-clock seconds the harness itself took.
    pub wall_secs: f64,
}

impl LoadReport {
    fn from_sims(sims: Vec<ReplicaSim>, arrivals: &[f64], spec: &LoadSpec, wall: f64) -> Self {
        let offered = arrivals.len() as u64;
        let admitted: u64 = sims.iter().map(|s| s.admitted).sum();
        let shed_queue: u64 = sims.iter().map(|s| s.shed_queue).sum();
        let shed_deadline: u64 = sims.iter().map(|s| s.shed_deadline).sum();
        let misses: u64 = sims.iter().map(|s| s.slo_misses).sum();
        let mut histogram = LatencyHistogram::new();
        for s in &sims {
            histogram.merge(&s.hist);
        }
        let first = arrivals.first().copied().unwrap_or(0.0);
        let last = arrivals.last().copied().unwrap_or(0.0);
        let horizon = sims.iter().map(|s| s.horizon).fold(0.0f64, f64::max);
        let makespan = if admitted > 0 { horizon - first } else { 0.0 };
        let span = last - first;
        LoadReport {
            offered,
            admitted,
            shed_queue,
            shed_deadline,
            shed_rate: if offered > 0 {
                (shed_queue + shed_deadline) as f64 / offered as f64
            } else {
                0.0
            },
            offered_rate: if span > 0.0 {
                (offered.saturating_sub(1)) as f64 / span
            } else {
                0.0
            },
            makespan,
            throughput: if makespan > 0.0 {
                admitted as f64 / makespan
            } else {
                0.0
            },
            mean_latency: histogram.mean(),
            p50: histogram.quantile(0.50),
            p95: histogram.quantile(0.95),
            p99: histogram.quantile(0.99),
            p999: histogram.quantile(0.999),
            slo: spec.deadline.map(|deadline| SloReport {
                deadline,
                misses,
                miss_rate: if admitted > 0 {
                    misses as f64 / admitted as f64
                } else {
                    0.0
                },
            }),
            per_replica: sims
                .iter()
                .enumerate()
                .map(|(replica, s)| ReplicaLoad {
                    replica,
                    admitted: s.admitted,
                    shed: s.shed_queue + s.shed_deadline,
                    horizon: s.horizon,
                })
                .collect(),
            histogram,
            wall_secs: wall,
        }
    }
}

fn timed<R>(f: impl FnOnce() -> R) -> (R, f64) {
    let t0 = std::time::Instant::now();
    let r = f();
    (r, t0.elapsed().as_secs_f64())
}

/// Run `spec` through the sharded threaded harness over `replicas`
/// (one stage-profile vector per pipeline replica).
pub fn run_load(replicas: &[Vec<StageProfile>], spec: &LoadSpec) -> LoadReport {
    let arrivals = spec.process.generate(spec.n_requests, spec.seed);
    let opts = spec.offer_options();
    let (sims, wall) = timed(|| {
        dispatch::run_sharded(replicas, &arrivals, &opts, spec.threads, spec.channel_capacity)
    });
    LoadReport::from_sims(sims, &arrivals, spec, wall)
}

/// [`run_load`] through the single-global-Mutex baseline — identical
/// results, contended wall-clock; the serving bench's comparison arm.
pub fn run_load_mutexed(replicas: &[Vec<StageProfile>], spec: &LoadSpec) -> LoadReport {
    let arrivals = spec.process.generate(spec.n_requests, spec.seed);
    let opts = spec.offer_options();
    let (sims, wall) = timed(|| {
        dispatch::run_mutexed(replicas, &arrivals, &opts, spec.threads, spec.channel_capacity)
    });
    LoadReport::from_sims(sims, &arrivals, spec, wall)
}

/// [`run_load`] through the sequential analytic twin (no threads, no
/// rings) — the ground truth the agreement test compares against.
pub fn run_load_reference(replicas: &[Vec<StageProfile>], spec: &LoadSpec) -> LoadReport {
    let arrivals = spec.process.generate(spec.n_requests, spec.seed);
    let opts = spec.offer_options();
    let (sims, wall) = timed(|| dispatch::run_reference(replicas, &arrivals, &opts));
    LoadReport::from_sims(sims, &arrivals, spec, wall)
}

/// One cell of the shed-rate curve sweep.
#[derive(Debug, Clone, Copy)]
pub struct SweepPoint {
    pub rate: f64,
    pub replicas: usize,
    pub throughput: f64,
    pub p99: f64,
    pub shed_rate: f64,
}

/// Sweep Poisson arrival rate × replica count over copies of one
/// pipeline profile, via the analytic twin (the sweep is about the
/// curve shape, not harness wall-clock). Rows come back in
/// (replicas, rate) order.
pub fn sweep_shed_curve(
    profile: &[StageProfile],
    rates: &[f64],
    replica_counts: &[usize],
    base: &LoadSpec,
) -> Vec<SweepPoint> {
    let mut out = Vec::with_capacity(rates.len() * replica_counts.len());
    for &r in replica_counts {
        assert!(r >= 1, "replica count must be >= 1");
        let replicas: Vec<Vec<StageProfile>> = vec![profile.to_vec(); r];
        for &rate in rates {
            let spec = LoadSpec { process: ArrivalProcess::Poisson { rate }, ..base.clone() };
            let rep = run_load_reference(&replicas, &spec);
            out.push(SweepPoint {
                rate,
                replicas: r,
                throughput: rep.throughput,
                p99: rep.p99,
                shed_rate: rep.shed_rate,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> Vec<StageProfile> {
        vec![StageProfile::constant(0.002), StageProfile::constant(0.003)]
    }

    /// Under Miri the threaded runs are ~1000x slower; keep the same
    /// shapes on a 20x smaller trace (still large enough for the
    /// rate-estimate tolerances below).
    fn scaled(n: usize) -> usize {
        if cfg!(miri) {
            n / 20
        } else {
            n
        }
    }

    #[test]
    fn underload_sheds_nothing_and_meets_rate() {
        // 2 replicas at period 3ms each ~ 666 req/s capacity; offer 200.
        let replicas = vec![profile(), profile()];
        let spec = LoadSpec {
            process: ArrivalProcess::Poisson { rate: 200.0 },
            n_requests: scaled(5_000),
            ..Default::default()
        };
        let rep = run_load(&replicas, &spec);
        assert_eq!(rep.admitted, scaled(5_000) as u64);
        assert_eq!(rep.shed_rate, 0.0);
        assert!((rep.offered_rate - 200.0).abs() < 20.0, "rate {}", rep.offered_rate);
        assert!(rep.p50 >= 0.005 - 1e-9, "p50 below bare latency: {}", rep.p50);
        assert!(rep.p999 >= rep.p99 && rep.p99 >= rep.p50);
    }

    #[test]
    fn overload_sheds_and_caps_throughput() {
        // 1 replica, period 3ms ~ 333 req/s; offer 2000 req/s, cap 8.
        let replicas = vec![profile()];
        let spec = LoadSpec {
            process: ArrivalProcess::Poisson { rate: 2000.0 },
            n_requests: scaled(20_000),
            queue_capacity: 8,
            ..Default::default()
        };
        let rep = run_load(&replicas, &spec);
        assert!(rep.shed_rate > 0.5, "shed_rate {}", rep.shed_rate);
        assert!(rep.throughput < 400.0, "throughput {}", rep.throughput);
        assert_eq!(rep.admitted + rep.shed_queue + rep.shed_deadline, rep.offered);
    }

    #[test]
    fn slo_accounting_counts_deadline_misses() {
        let replicas = vec![profile()];
        let spec = LoadSpec {
            process: ArrivalProcess::Poisson { rate: 1000.0 },
            n_requests: scaled(5_000),
            queue_capacity: 32,
            deadline: Some(0.006),
            ..Default::default()
        };
        let rep = run_load(&replicas, &spec);
        let slo = rep.slo.expect("deadline set");
        assert!(slo.misses > 0, "overloaded run should miss some deadlines");
        assert!(slo.miss_rate > 0.0 && slo.miss_rate <= 1.0);
    }

    #[test]
    fn sweep_shed_rate_monotone_in_rate_and_falls_with_replicas() {
        let base = LoadSpec { n_requests: scaled(4_000), queue_capacity: 8, ..Default::default() };
        let pts = sweep_shed_curve(&profile(), &[100.0, 500.0, 2500.0], &[1, 4], &base);
        assert_eq!(pts.len(), 6);
        for pair in pts.chunks(3) {
            assert!(pair[0].shed_rate <= pair[1].shed_rate + 1e-9);
            assert!(pair[1].shed_rate <= pair[2].shed_rate + 1e-9);
        }
        // At the highest rate, 4 replicas shed less than 1.
        let r1 = &pts[2];
        let r4 = &pts[5];
        assert!(r4.shed_rate < r1.shed_rate, "r4 {} vs r1 {}", r4.shed_rate, r1.shed_rate);
    }

    #[test]
    fn hundred_percent_shed_yields_defined_stats() {
        // Deadline shorter than any possible service: every request is
        // predicted late and shed; nothing is ever admitted.
        let replicas = vec![profile()];
        let spec = LoadSpec {
            process: ArrivalProcess::ConstantRate { rate: 100.0 },
            n_requests: 500,
            deadline: Some(1e-12),
            shed_on_deadline: true,
            ..Default::default()
        };
        for rep in [run_load(&replicas, &spec), run_load_reference(&replicas, &spec)] {
            assert_eq!(rep.admitted, 0);
            assert_eq!(rep.shed_deadline, 500);
            assert_eq!(rep.shed_rate, 1.0);
            let stats = [rep.throughput, rep.mean_latency, rep.p50, rep.p99, rep.p999];
            for v in stats {
                assert!(v == 0.0 && v.is_finite(), "expected defined zero, got {v}");
            }
            assert_eq!(rep.makespan, 0.0);
            let slo = rep.slo.expect("deadline set");
            assert_eq!(slo.misses, 0);
            assert_eq!(slo.miss_rate, 0.0);
        }
    }
}
