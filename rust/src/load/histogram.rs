//! Fixed-memory HDR-style latency histogram.
//!
//! A million-request open-loop run must report p50/p95/p99/p99.9
//! without keeping a million `f64`s alive. [`LatencyHistogram`] records
//! each latency into one of ~1.9k fixed buckets: integer microseconds,
//! exact below 64µs, then 32 sub-buckets per power-of-two octave —
//! log-linear, the classic HdrHistogram layout. Worst-case relative
//! quantile error is one sub-bucket width: `2^-5 ≈ 3.1%`. Counts, sum,
//! min and max are tracked exactly, so the mean is exact and quantiles
//! are clamped into the observed range.
//!
//! Recording is order-independent (bucket increments commute), which is
//! what lets the sharded threaded harness and the sequential analytic
//! twin produce *identical* histograms for the same request outcomes —
//! the open-loop agreement test compares quantiles at `== 0` tolerance.

/// Linear buckets below this value (µs): one bucket per microsecond.
const LINEAR_MAX: u64 = 64;
/// Sub-buckets per octave above the linear range (2^5).
const SUB_BITS: u32 = 5;
const SUB_BUCKETS: usize = 1 << SUB_BITS;
/// First octave exponent covered by the log range (2^6 = 64µs).
const FIRST_EXP: u32 = 6;
/// 64 linear buckets + 32 sub-buckets for each octave 2^6..2^63.
const N_BUCKETS: usize = LINEAR_MAX as usize + (64 - FIRST_EXP as usize) * SUB_BUCKETS;

fn bucket_index(us: u64) -> usize {
    if us < LINEAR_MAX {
        us as usize
    } else {
        let exp = 63 - us.leading_zeros();
        let sub = ((us >> (exp - SUB_BITS)) as usize) & (SUB_BUCKETS - 1);
        LINEAR_MAX as usize + (exp - FIRST_EXP) as usize * SUB_BUCKETS + sub
    }
}

/// Representative value (µs) reported for a bucket: its midpoint.
fn bucket_mid(idx: usize) -> f64 {
    if idx < LINEAR_MAX as usize {
        idx as f64
    } else {
        let rel = idx - LINEAR_MAX as usize;
        let exp = FIRST_EXP + (rel / SUB_BUCKETS) as u32;
        let sub = (rel % SUB_BUCKETS) as u64;
        let width = 1u64 << (exp - SUB_BITS);
        let lo = (1u64 << exp) + sub * width;
        lo as f64 + width as f64 / 2.0
    }
}

/// Fixed-bucket log-linear latency histogram (values in seconds,
/// stored as integer microseconds).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    /// Exact sum of recorded values, in µs.
    sum_us: u64,
    min_us: u64,
    max_us: u64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram {
            buckets: vec![0; N_BUCKETS],
            count: 0,
            sum_us: 0,
            min_us: u64::MAX,
            max_us: 0,
        }
    }

    /// Record one latency (seconds; negative values clamp to zero).
    pub fn record(&mut self, secs: f64) {
        let us = (secs.max(0.0) * 1e6).round() as u64;
        self.buckets[bucket_index(us)] += 1;
        self.count += 1;
        self.sum_us = self.sum_us.saturating_add(us);
        self.min_us = self.min_us.min(us);
        self.max_us = self.max_us.max(us);
    }

    /// Fold `other` into `self`. Bucket counts commute, so merge order
    /// does not change any reported quantile.
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
        self.min_us = self.min_us.min(other.min_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact mean of recorded latencies (seconds); defined 0.0 when
    /// empty — the zero-admitted guard the 100%-shed test pins.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64 / 1e6
        }
    }

    /// Exact observed maximum (seconds); 0.0 when empty.
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max_us as f64 / 1e6
        }
    }

    /// Quantile `p` in [0, 1] (seconds): midpoint of the bucket holding
    /// the rank-`ceil(p·count)` sample, clamped into the exact observed
    /// [min, max]. Defined 0.0 when empty.
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((p.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (idx, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let mid = bucket_mid(idx);
                let clamped = mid.clamp(self.min_us as f64, self.max_us as f64);
                return clamped / 1e6;
            }
        }
        self.max_us as f64 / 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_range_is_exact() {
        let mut h = LatencyHistogram::new();
        for us in [0u64, 1, 5, 63] {
            h.record(us as f64 / 1e6);
        }
        assert_eq!(h.count(), 4);
        // Every recorded value sits in its own exact bucket.
        assert!((h.quantile(0.0) - 0.0).abs() < 1e-12);
        assert!((h.quantile(1.0) - 63e-6).abs() < 1e-12);
        assert!((h.mean() - (0.0 + 1.0 + 5.0 + 63.0) / 4.0 / 1e6).abs() < 1e-15);
    }

    #[test]
    fn log_range_relative_error_bounded() {
        let mut h = LatencyHistogram::new();
        let values = [100e-6, 1e-3, 10e-3, 0.1, 1.0, 10.0, 100.0];
        for &v in &values {
            let mut solo = LatencyHistogram::new();
            solo.record(v);
            let q = solo.quantile(0.5);
            assert!((q - v).abs() / v < 0.032, "value {v}: got {q}");
            h.record(v);
        }
        assert_eq!(h.count(), values.len() as u64);
    }

    #[test]
    fn quantiles_monotone_and_clamped() {
        let mut h = LatencyHistogram::new();
        let mut r = crate::util::Rng::new(9);
        for _ in 0..10_000 {
            h.record(r.f64() * 0.5);
        }
        let mut prev = 0.0;
        for p in [0.1, 0.5, 0.9, 0.95, 0.99, 0.999, 1.0] {
            let q = h.quantile(p);
            assert!(q >= prev, "p{p}: {q} < {prev}");
            assert!(q <= h.max() + 1e-12);
            prev = q;
        }
    }

    #[test]
    fn empty_histogram_is_defined() {
        let h = LatencyHistogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn merge_matches_single_stream() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        let mut whole = LatencyHistogram::new();
        let mut r = crate::util::Rng::new(3);
        for i in 0..5_000 {
            let v = r.f64() * 2.0;
            whole.record(v);
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        for p in [0.5, 0.95, 0.99, 0.999] {
            assert_eq!(a.quantile(p), whole.quantile(p), "p{p}");
        }
        assert!((a.mean() - whole.mean()).abs() < 1e-15);
    }
}
