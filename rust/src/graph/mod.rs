//! CNN model graphs: the paper's `G : (V, E)` (§3.1.1).
//!
//! A [`ModelGraph`] is a DAG of [`Layer`]s stored in topological order,
//! with shape inference matching `python/compile/model.py` exactly, width
//! computation (Definition 6, via Dilworth / maximum antichain) and
//! [`Segment`] views (Definitions 1–3: sources, sinks, ending pieces).

mod layer;
mod model;
mod segment;
mod width;

pub use layer::{Activation, Layer, Op};
pub use model::{ModelGraph, Shape};
pub use segment::Segment;
pub use width::width;

/// Layer id: index into `ModelGraph::layers` (topological order).
pub type LayerId = usize;
