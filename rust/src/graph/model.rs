//! `ModelGraph`: the CNN DAG with shape inference and JSON interchange.

use std::collections::BTreeMap;

use super::{Activation, Layer, LayerId, Op};
use crate::json::{obj, Value};

/// Output shape of a layer: spatial feature map or flat vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Shape {
    /// (C, H, W)
    Chw(usize, usize, usize),
    /// (N,)
    Flat(usize),
}

impl Shape {
    pub fn elems(&self) -> usize {
        match self {
            Shape::Chw(c, h, w) => c * h * w,
            Shape::Flat(n) => *n,
        }
    }

    pub fn bytes(&self) -> usize {
        self.elems() * 4 // f32
    }

    /// Feature-map height (1 for flat vectors).
    pub fn height(&self) -> usize {
        match self {
            Shape::Chw(_, h, _) => *h,
            Shape::Flat(_) => 1,
        }
    }

    pub fn channels(&self) -> usize {
        match self {
            Shape::Chw(c, _, _) => *c,
            Shape::Flat(n) => *n,
        }
    }

    pub fn width(&self) -> usize {
        match self {
            Shape::Chw(_, _, w) => *w,
            Shape::Flat(_) => 1,
        }
    }
}

/// The CNN DAG `G : (V, E)`. Layers are stored in topological order
/// (builders append producers before consumers; `from_json` validates).
#[derive(Debug, Clone)]
pub struct ModelGraph {
    pub name: String,
    /// Input feature shape (C, H, W).
    pub input_shape: (usize, usize, usize),
    pub layers: Vec<Layer>,
    /// consumers[i] = layers that read layer i's output.
    consumers: Vec<Vec<LayerId>>,
    /// Cached per-layer output shapes.
    shapes: Vec<Shape>,
}

impl ModelGraph {
    /// Build from topologically ordered layers; computes shapes eagerly
    /// and validates the DAG invariants.
    pub fn new(
        name: &str,
        input_shape: (usize, usize, usize),
        layers: Vec<Layer>,
    ) -> anyhow::Result<ModelGraph> {
        let mut g = ModelGraph {
            name: name.to_string(),
            input_shape,
            consumers: vec![Vec::new(); layers.len()],
            shapes: Vec::with_capacity(layers.len()),
            layers,
        };
        for (i, l) in g.layers.iter().enumerate() {
            for &src in &l.inputs {
                anyhow::ensure!(src < i, "layer {} ({}) reads later layer {}", i, l.name, src);
            }
            if l.op == Op::Input {
                anyhow::ensure!(l.inputs.is_empty(), "input layer {} has inputs", l.name);
                anyhow::ensure!(i == 0, "input layer {} must be first", l.name);
            }
        }
        anyhow::ensure!(!g.layers.is_empty(), "empty model");
        anyhow::ensure!(g.layers[0].op == Op::Input, "first layer must be input");
        for (i, l) in g.layers.iter().enumerate() {
            for &src in &l.inputs {
                g.consumers[src].push(i);
            }
        }
        g.shapes = g.infer_shapes()?;
        Ok(g)
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Paper's n: conv + pool vertices only (§6.2.3, Table 4 footnote).
    pub fn n_conv_pool(&self) -> usize {
        self.layers.iter().filter(|l| l.op.is_spatial()).count()
    }

    pub fn layer(&self, id: LayerId) -> &Layer {
        &self.layers[id]
    }

    pub fn consumers(&self, id: LayerId) -> &[LayerId] {
        &self.consumers[id]
    }

    pub fn shape(&self, id: LayerId) -> Shape {
        self.shapes[id]
    }

    pub fn output_id(&self) -> LayerId {
        self.layers.len() - 1
    }

    pub fn by_name(&self, name: &str) -> Option<LayerId> {
        self.layers.iter().position(|l| l.name == name)
    }

    /// Input channel count seen by layer `id` (sum over concat inputs).
    pub fn in_channels(&self, id: LayerId) -> usize {
        let l = &self.layers[id];
        if l.inputs.is_empty() {
            return self.input_shape.0;
        }
        match l.op {
            Op::Concat => l.inputs.iter().map(|&i| self.shapes[i].channels()).sum(),
            _ => self.shapes[l.inputs[0]].channels(),
        }
    }

    fn infer_shapes(&self) -> anyhow::Result<Vec<Shape>> {
        let mut shapes: Vec<Shape> = Vec::with_capacity(self.layers.len());
        for (i, l) in self.layers.iter().enumerate() {
            let ins: Vec<Shape> = l.inputs.iter().map(|&j| shapes[j]).collect();
            let s = match l.op {
                Op::Input => {
                    let (c, h, w) = self.input_shape;
                    Shape::Chw(c, h, w)
                }
                Op::Conv | Op::MaxPool | Op::AvgPool => {
                    let Shape::Chw(c, h, w) = ins[0] else {
                        anyhow::bail!("{}: spatial op on flat input", l.name)
                    };
                    let (kh, kw) = l.kernel;
                    let (sh, sw) = l.stride;
                    let (ph, pw) = l.padding;
                    anyhow::ensure!(
                        h + 2 * ph >= kh && w + 2 * pw >= kw,
                        "{}: window exceeds input",
                        l.name
                    );
                    let ho = (h + 2 * ph - kh) / sh + 1;
                    let wo = (w + 2 * pw - kw) / sw + 1;
                    let co = if l.op == Op::Conv { l.out_channels } else { c };
                    Shape::Chw(co, ho, wo)
                }
                Op::Add => {
                    anyhow::ensure!(
                        ins.iter().all(|s| *s == ins[0]),
                        "{}: add inputs disagree: {ins:?}",
                        l.name
                    );
                    ins[0]
                }
                Op::Concat => {
                    let Shape::Chw(_, h, w) = ins[0] else {
                        anyhow::bail!("{}: concat on flat input", l.name)
                    };
                    let mut c = 0;
                    for s in &ins {
                        let Shape::Chw(ci, hi, wi) = s else {
                            anyhow::bail!("{}: concat on flat input", l.name)
                        };
                        anyhow::ensure!(
                            *hi == h && *wi == w,
                            "{}: concat spatial mismatch",
                            l.name
                        );
                        c += ci;
                    }
                    Shape::Chw(c, h, w)
                }
                Op::Flatten => Shape::Flat(ins[0].elems()),
                Op::Dense => {
                    anyhow::ensure!(
                        matches!(ins[0], Shape::Flat(_)),
                        "{}: dense on spatial input",
                        l.name
                    );
                    Shape::Flat(l.out_channels)
                }
            };
            if l.op != Op::Input {
                anyhow::ensure!(!l.inputs.is_empty(), "{}: non-input layer without inputs", l.name);
            }
            let _ = i;
            shapes.push(s);
        }
        Ok(shapes)
    }

    // ------------------------------------------------------------ JSON

    /// Load from the spec.json format produced by `python/compile/model.py`.
    pub fn from_json(v: &Value) -> anyhow::Result<ModelGraph> {
        let name = v.get("name").as_str().unwrap_or("model").to_string();
        let ishape = v.get("input_shape");
        let input_shape = (
            ishape.idx(0).as_usize().ok_or_else(|| anyhow::anyhow!("bad input_shape"))?,
            ishape.idx(1).as_usize().unwrap_or(1),
            ishape.idx(2).as_usize().unwrap_or(1),
        );
        let mut ids: BTreeMap<String, LayerId> = BTreeMap::new();
        let mut layers = Vec::new();
        for lv in v.get("layers").as_arr().ok_or_else(|| anyhow::anyhow!("missing layers"))? {
            let lname =
                lv.get("name").as_str().ok_or_else(|| anyhow::anyhow!("layer without name"))?;
            let op = Op::from_str(lv.get("op").as_str().unwrap_or(""))?;
            let mut inputs = Vec::new();
            for iv in lv.get("inputs").as_arr().unwrap_or(&[]) {
                let iname = iv.as_str().ok_or_else(|| anyhow::anyhow!("bad input ref"))?;
                let id = ids.get(iname).ok_or_else(|| {
                    anyhow::anyhow!("{lname}: unknown input {iname} (not topo-ordered?)")
                })?;
                inputs.push(*id);
            }
            let pair = |key: &str, default: usize| -> (usize, usize) {
                let a = lv.get(key);
                (a.idx(0).as_usize().unwrap_or(default), a.idx(1).as_usize().unwrap_or(default))
            };
            let act = lv.get("activation").as_str().unwrap_or("linear");
            let layer = Layer {
                name: lname.to_string(),
                op,
                inputs,
                out_channels: lv.get("out_channels").as_usize().unwrap_or(0),
                kernel: pair("kernel", 1),
                stride: pair("stride", 1),
                padding: pair("padding", 0),
                activation: Activation::from_str(act)?,
                groups: lv.get("groups").as_usize().unwrap_or(1),
            };
            ids.insert(lname.to_string(), layers.len());
            layers.push(layer);
        }
        ModelGraph::new(&name, input_shape, layers)
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<ModelGraph> {
        ModelGraph::from_json(&Value::from_file(path)?)
    }

    pub fn to_json(&self) -> Value {
        let layers: Vec<Value> = self
            .layers
            .iter()
            .map(|l| {
                let input_names: Vec<Value> =
                    l.inputs.iter().map(|&i| self.layers[i].name.as_str().into()).collect();
                obj(vec![
                    ("name", l.name.as_str().into()),
                    ("op", l.op.as_str().into()),
                    ("inputs", Value::Arr(input_names)),
                    ("out_channels", l.out_channels.into()),
                    ("kernel", vec![l.kernel.0, l.kernel.1].into()),
                    ("stride", vec![l.stride.0, l.stride.1].into()),
                    ("padding", vec![l.padding.0, l.padding.1].into()),
                    ("activation", l.activation.as_str().into()),
                    ("groups", l.groups.into()),
                ])
            })
            .collect();
        obj(vec![
            ("name", self.name.as_str().into()),
            (
                "input_shape",
                vec![self.input_shape.0, self.input_shape.1, self.input_shape.2].into(),
            ),
            ("layers", Value::Arr(layers)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> ModelGraph {
        let l = vec![
            Layer::input("in"),
            Layer::conv("c1", 0, 8, (3, 3), (1, 1), (1, 1), Activation::Relu),
            Layer::maxpool("p1", 1, (2, 2), (2, 2), (0, 0)),
            Layer::conv("c2", 2, 16, (3, 3), (1, 1), (1, 1), Activation::Relu),
            Layer::flatten("f", 3),
            Layer::dense("d", 4, 10, Activation::Linear),
        ];
        ModelGraph::new("chain", (3, 32, 32), l).unwrap()
    }

    #[test]
    fn shapes_chain() {
        let g = chain();
        assert_eq!(g.shape(1), Shape::Chw(8, 32, 32));
        assert_eq!(g.shape(2), Shape::Chw(8, 16, 16));
        assert_eq!(g.shape(3), Shape::Chw(16, 16, 16));
        assert_eq!(g.shape(4), Shape::Flat(16 * 16 * 16));
        assert_eq!(g.shape(5), Shape::Flat(10));
        assert_eq!(g.n_conv_pool(), 3);
    }

    #[test]
    fn consumers_tracked() {
        let g = chain();
        assert_eq!(g.consumers(0), &[1]);
        assert_eq!(g.consumers(1), &[2]);
        assert_eq!(g.consumers(5), &[] as &[usize]);
    }

    #[test]
    fn dag_shapes() {
        let l = vec![
            Layer::input("in"),
            Layer::conv("stem", 0, 8, (3, 3), (1, 1), (1, 1), Activation::Relu),
            Layer::conv("a", 1, 4, (1, 1), (1, 1), (0, 0), Activation::Relu),
            Layer::conv("b", 1, 4, (3, 3), (1, 1), (1, 1), Activation::Relu),
            Layer::concat("cat", vec![2, 3]),
            Layer::add("skip", vec![4, 1]),
        ];
        let g = ModelGraph::new("dag", (3, 16, 16), l).unwrap();
        assert_eq!(g.shape(4), Shape::Chw(8, 16, 16));
        assert_eq!(g.shape(5), Shape::Chw(8, 16, 16));
        assert_eq!(g.in_channels(4), 8);
    }

    #[test]
    fn add_mismatch_rejected() {
        let l = vec![
            Layer::input("in"),
            Layer::conv("a", 0, 4, (3, 3), (1, 1), (1, 1), Activation::Relu),
            Layer::conv("b", 0, 8, (3, 3), (1, 1), (1, 1), Activation::Relu),
            Layer::add("bad", vec![1, 2]),
        ];
        assert!(ModelGraph::new("bad", (3, 16, 16), l).is_err());
    }

    #[test]
    fn forward_ref_rejected() {
        let mut c1 = Layer::conv("c1", 0, 8, (3, 3), (1, 1), (1, 1), Activation::Relu);
        c1.inputs = vec![2]; // reads a later layer
        let l = vec![
            Layer::input("in"),
            c1,
            Layer::conv("c2", 0, 8, (3, 3), (1, 1), (1, 1), Activation::Relu),
        ];
        assert!(ModelGraph::new("bad", (3, 16, 16), l).is_err());
    }

    #[test]
    fn json_roundtrip() {
        let g = chain();
        let v = g.to_json();
        let g2 = ModelGraph::from_json(&v).unwrap();
        assert_eq!(g2.n_layers(), g.n_layers());
        for i in 0..g.n_layers() {
            assert_eq!(g2.shape(i), g.shape(i));
            assert_eq!(g2.layer(i).name, g.layer(i).name);
            assert_eq!(g2.layer(i).op, g.layer(i).op);
        }
    }
}
