//! Width of a CNN (paper Definition 6): the largest set of *neural layers*
//! (conv/pool vertices) with no path connecting any two of them — a
//! maximum antichain of the reachability partial order.
//!
//! By Dilworth's theorem the maximum antichain equals the minimum chain
//! cover, computed as |S| − (maximum matching) on the bipartite
//! comparability graph over the transitive closure. n ≤ ~600 for every
//! model in the zoo, so bitset closure + Kuhn's matching is plenty.

use super::ModelGraph;
use crate::util::BitSet;

/// Maximum-antichain width over conv/pool vertices.
pub fn width(g: &ModelGraph) -> usize {
    let n = g.n_layers();
    // Transitive closure over ALL vertices (paths may run through
    // connectors), reverse topological order.
    let mut reach: Vec<BitSet> = vec![BitSet::new(n); n];
    for u in (0..n).rev() {
        let mut r = BitSet::new(n);
        for &v in g.consumers(u) {
            r.insert(v);
            r = r.union(&reach[v]);
        }
        reach[u] = r;
    }
    let spatial: Vec<usize> = (0..n).filter(|&i| g.layer(i).op.is_spatial()).collect();
    if spatial.is_empty() {
        return 0;
    }
    let index_of: std::collections::HashMap<usize, usize> =
        spatial.iter().enumerate().map(|(k, &id)| (id, k)).collect();
    let m = spatial.len();
    // adj[k] = spatial vertices reachable from spatial[k].
    let adj: Vec<Vec<usize>> = spatial
        .iter()
        .map(|&u| reach[u].iter().filter_map(|v| index_of.get(&v).copied()).collect())
        .collect();
    // Kuhn's bipartite maximum matching.
    let mut matched_right: Vec<Option<usize>> = vec![None; m];
    let mut matching = 0;
    for u in 0..m {
        let mut seen = vec![false; m];
        if try_kuhn(u, &adj, &mut seen, &mut matched_right) {
            matching += 1;
        }
    }
    m - matching
}

fn try_kuhn(
    u: usize,
    adj: &[Vec<usize>],
    seen: &mut [bool],
    matched_right: &mut [Option<usize>],
) -> bool {
    for &v in &adj[u] {
        if !seen[v] {
            seen[v] = true;
            if matched_right[v].is_none()
                || try_kuhn(matched_right[v].unwrap(), adj, seen, matched_right)
            {
                matched_right[v] = Some(u);
                return true;
            }
        }
    }
    false
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, Layer, ModelGraph};

    fn conv(n: &str, i: usize) -> Layer {
        Layer::conv(n, i, 4, (3, 3), (1, 1), (1, 1), Activation::Relu)
    }

    #[test]
    fn chain_width_is_one() {
        let layers = vec![Layer::input("in"), conv("a", 0), conv("b", 1), conv("c", 2)];
        let g = ModelGraph::new("chain", (3, 8, 8), layers).unwrap();
        assert_eq!(width(&g), 1);
    }

    #[test]
    fn parallel_branches_width() {
        // stem fans out to 3 parallel convs, concat joins.
        let layers = vec![
            Layer::input("in"),
            conv("stem", 0),
            conv("b1", 1),
            conv("b2", 1),
            conv("b3", 1),
            Layer::concat("cat", vec![2, 3, 4]),
            conv("tail", 5),
        ];
        let g = ModelGraph::new("branch3", (3, 8, 8), layers).unwrap();
        assert_eq!(width(&g), 3);
    }

    #[test]
    fn path_through_connector_counts() {
        // a → add → b: a and b are connected through the connector, so
        // they cannot be in one antichain together.
        let layers = vec![
            Layer::input("in"),
            conv("a", 0),
            Layer::add("mid", vec![1, 1]),
            conv("b", 2),
        ];
        let g = ModelGraph::new("thread", (3, 8, 8), layers).unwrap();
        assert_eq!(width(&g), 1);
    }

    #[test]
    fn skip_connection_width_two() {
        // ResNet-ish: main path has two convs, projection conv parallel.
        let layers = vec![
            Layer::input("in"),
            conv("stem", 0),
            conv("m1", 1),
            conv("m2", 2),
            conv("proj", 1),
            Layer::add("add", vec![3, 4]),
        ];
        let g = ModelGraph::new("skip", (3, 8, 8), layers).unwrap();
        assert_eq!(width(&g), 2);
    }
}
