//! Segments (paper §3.1.1, Definitions 1–4): subsets of the CNN DAG that
//! keep the edges crossing their boundary, with source/sink/ending-piece
//! queries and the diameter used by Algorithm 1's pruning (Definition 5).

use super::{LayerId, ModelGraph};
use crate::util::BitSet;

/// A segment `M : (V, E)` of a model graph — a set of vertices plus, by
/// Definition 1, every edge incident to them (boundary edges included,
/// which is why sources/sinks are defined via edges whose other endpoint
/// lies outside).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Segment {
    pub members: BitSet,
}

impl Segment {
    pub fn new(members: BitSet) -> Segment {
        Segment { members }
    }

    pub fn from_ids(ids: impl IntoIterator<Item = LayerId>) -> Segment {
        Segment { members: ids.into_iter().collect() }
    }

    pub fn contains(&self, id: LayerId) -> bool {
        self.members.contains(id)
    }

    pub fn len(&self) -> usize {
        self.members.len()
    }

    pub fn is_empty(&self) -> bool {
        self.members.is_empty()
    }

    pub fn ids(&self) -> Vec<LayerId> {
        self.members.iter().collect()
    }

    /// Definition 2: v is a *source* if some edge (u, v) has u outside the
    /// segment. Layers with no inputs at all (the model input) also count
    /// as sources — they are fed from outside the model.
    pub fn sources(&self, g: &ModelGraph) -> Vec<LayerId> {
        self.members
            .iter()
            .filter(|&v| {
                let l = g.layer(v);
                l.inputs.is_empty() || l.inputs.iter().any(|u| !self.members.contains(*u))
            })
            .collect()
    }

    /// Definition 3: u is a *sink* if some edge (u, v) has v outside the
    /// segment; the model output layer is a sink of any segment holding it.
    pub fn sinks(&self, g: &ModelGraph) -> Vec<LayerId> {
        self.members
            .iter()
            .filter(|&u| {
                let cons = g.consumers(u);
                cons.is_empty() || cons.iter().any(|v| !self.members.contains(*v))
            })
            .collect()
    }

    /// External producers feeding this segment (the previous stage's
    /// sinks, from this segment's point of view).
    pub fn feeds(&self, g: &ModelGraph) -> Vec<LayerId> {
        let mut out = BitSet::new(g.n_layers());
        for v in self.members.iter() {
            for &u in &g.layer(v).inputs {
                if !self.members.contains(u) {
                    out.insert(u);
                }
            }
        }
        out.iter().collect()
    }

    /// Definition 4: an *ending piece* of `g` restricted to `universe` —
    /// for any edge (u, v) with both endpoints in the universe, u in the
    /// piece implies v in the piece (no edge leaves the piece forward).
    pub fn is_ending_piece(&self, g: &ModelGraph, universe: &BitSet) -> bool {
        for u in self.members.iter() {
            for &v in g.consumers(u) {
                if universe.contains(v) && !self.members.contains(v) {
                    return false;
                }
            }
        }
        true
    }

    /// Definition 5: the diameter of a piece — the greatest path length
    /// (in edges) between any vertex pair inside the piece. Algorithm 1
    /// bounds this by `d` to prune the DFS enumeration.
    pub fn diameter(&self, g: &ModelGraph) -> usize {
        // Longest path in the induced sub-DAG; layers are topo-ordered so
        // one forward sweep suffices.
        let mut dist: Vec<usize> = vec![0; g.n_layers()];
        let mut best = 0;
        for v in self.members.iter() {
            for &u in &g.layer(v).inputs {
                if self.members.contains(u) {
                    dist[v] = dist[v].max(dist[u] + 1);
                }
            }
            best = best.max(dist[v]);
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Activation, Layer};

    /// Fig. 7's 8-vertex graph:
    /// A→B→C→E→G, A→D→F→H, C→F, E→H wired as a conv DAG.
    fn fig7() -> ModelGraph {
        let c = |n: &str, i: Vec<usize>| -> Layer {
            if i.len() == 1 {
                Layer::conv(n, i[0], 4, (3, 3), (1, 1), (1, 1), Activation::Relu)
            } else {
                Layer::add(n, i)
            }
        };
        let layers = vec![
            Layer::input("in"),      // 0
            c("a", vec![0]),         // 1
            c("b", vec![1]),         // 2
            c("c", vec![2]),         // 3
            c("d", vec![1]),         // 4
            c("e", vec![3]),         // 5
            c("f", vec![3, 4]),      // 6 (add: C, D)
            c("g", vec![5]),         // 7
            c("h", vec![5, 6]),      // 8 (add: E, F)
        ];
        ModelGraph::new("fig7", (3, 16, 16), layers).unwrap()
    }

    #[test]
    fn sources_and_sinks() {
        let g = fig7();
        let m = Segment::from_ids([5, 7, 8]); // {E, G, H}
        assert_eq!(m.sources(&g), vec![5, 8]); // E fed by C; H fed by F
        assert_eq!(m.sinks(&g), vec![7, 8]);
        assert_eq!(m.feeds(&g), vec![3, 6]);
    }

    #[test]
    fn ending_piece_fig7() {
        let g = fig7();
        let universe = BitSet::full(g.n_layers());
        // {E, G, H} is an ending piece (Fig. 7b).
        assert!(Segment::from_ids([5, 7, 8]).is_ending_piece(&g, &universe));
        // {E, F, H} is not: E's consumer G is outside (Fig. 7c).
        assert!(!Segment::from_ids([5, 6, 8]).is_ending_piece(&g, &universe));
        // Restricted universe: once {E,G,H} removed, {B,C,F} is ending.
        let rest = universe.minus(&Segment::from_ids([5, 7, 8]).members);
        assert!(Segment::from_ids([2, 3, 6]).is_ending_piece(&g, &rest));
    }

    #[test]
    fn diameter_counts_edges() {
        let g = fig7();
        assert_eq!(Segment::from_ids([5, 7, 8]).diameter(&g), 1);
        assert_eq!(Segment::from_ids([1, 2, 3, 5]).diameter(&g), 3);
        assert_eq!(Segment::from_ids([4]).diameter(&g), 0);
        // Disconnected members: no in-piece path, diameter 0.
        assert_eq!(Segment::from_ids([2, 4]).diameter(&g), 0);
    }

    #[test]
    fn whole_graph_is_ending_piece() {
        let g = fig7();
        let universe = BitSet::full(g.n_layers());
        assert!(Segment::new(universe.clone()).is_ending_piece(&g, &universe));
    }
}
