//! Layer (DAG vertex) definitions, mirroring `python/compile/model.py`.

use super::LayerId;

/// Layer operation kind. `Add`/`Concat` are the paper's *connectors*
/// (Fig. 3); norm/activation layers are folded into conv's `activation`
/// as the paper does (§2.3: "the norm layer and activation layer are
/// ignored since they do not change the input and output shape").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    Input,
    Conv,
    MaxPool,
    AvgPool,
    Add,
    Concat,
    Flatten,
    Dense,
}

impl Op {
    pub fn as_str(&self) -> &'static str {
        match self {
            Op::Input => "input",
            Op::Conv => "conv",
            Op::MaxPool => "maxpool",
            Op::AvgPool => "avgpool",
            Op::Add => "add",
            Op::Concat => "concat",
            Op::Flatten => "flatten",
            Op::Dense => "dense",
        }
    }

    pub fn from_str(s: &str) -> anyhow::Result<Op> {
        Ok(match s {
            "input" => Op::Input,
            "conv" => Op::Conv,
            "maxpool" => Op::MaxPool,
            "avgpool" => Op::AvgPool,
            "add" => Op::Add,
            "concat" => Op::Concat,
            "flatten" => Op::Flatten,
            "dense" => Op::Dense,
            other => anyhow::bail!("unknown op {other:?}"),
        })
    }

    /// Spatial ops have (kernel, stride, padding) row geometry (Eq. 3).
    pub fn is_spatial(&self) -> bool {
        matches!(self, Op::Conv | Op::MaxPool | Op::AvgPool)
    }

    /// Connectors pass rows through unchanged (k=1, s=1, p=0).
    pub fn is_connector(&self) -> bool {
        matches!(self, Op::Add | Op::Concat)
    }
}

/// Activation fused into conv/dense layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Activation {
    #[default]
    Linear,
    Relu,
    /// Leaky ReLU, slope 0.1 (YOLO convention).
    Leaky,
}

impl Activation {
    pub fn as_str(&self) -> &'static str {
        match self {
            Activation::Linear => "linear",
            Activation::Relu => "relu",
            Activation::Leaky => "leaky",
        }
    }

    pub fn from_str(s: &str) -> anyhow::Result<Activation> {
        Ok(match s {
            "linear" => Activation::Linear,
            "relu" => Activation::Relu,
            "leaky" => Activation::Leaky,
            other => anyhow::bail!("unknown activation {other:?}"),
        })
    }

    pub fn apply(&self, x: f32) -> f32 {
        match self {
            Activation::Linear => x,
            Activation::Relu => x.max(0.0),
            Activation::Leaky => {
                if x > 0.0 {
                    x
                } else {
                    0.1 * x
                }
            }
        }
    }
}

/// One vertex `l_i` of the CNN DAG.
#[derive(Debug, Clone)]
pub struct Layer {
    pub name: String,
    pub op: Op,
    /// Producers of this layer's inputs (must precede it topologically).
    pub inputs: Vec<LayerId>,
    /// Conv: output channels `c_i`; Dense: output units.
    pub out_channels: usize,
    /// (kh, kw) — `k_i` in Eq. (3)-(4).
    pub kernel: (usize, usize),
    /// (sh, sw) — `s_i`.
    pub stride: (usize, usize),
    /// (ph, pw) — `p_i`.
    pub padding: (usize, usize),
    pub activation: Activation,
    /// Grouped convolution factor (1 = dense conv; c_in = depthwise).
    /// Used by MobileNet-style models; affects FLOPs (Eq. 4 with
    /// c_in' = c_in / groups) and weight memory.
    pub groups: usize,
}

impl Layer {
    /// Generic constructor; prefer the op-specific helpers below.
    pub fn new(name: &str, op: Op) -> Layer {
        Layer {
            name: name.to_string(),
            op,
            inputs: Vec::new(),
            out_channels: 0,
            kernel: (1, 1),
            stride: (1, 1),
            padding: (0, 0),
            activation: Activation::Linear,
            groups: 1,
        }
    }

    pub fn input(name: &str) -> Layer {
        Layer::new(name, Op::Input)
    }

    #[allow(clippy::too_many_arguments)]
    pub fn conv(
        name: &str,
        input: LayerId,
        out_channels: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: (usize, usize),
        activation: Activation,
    ) -> Layer {
        Layer {
            inputs: vec![input],
            out_channels,
            kernel,
            stride,
            padding,
            activation,
            ..Layer::new(name, Op::Conv)
        }
    }

    /// Depthwise/grouped conv (MobileNet, NASNet separable convs).
    #[allow(clippy::too_many_arguments)]
    pub fn conv_grouped(
        name: &str,
        input: LayerId,
        out_channels: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: (usize, usize),
        activation: Activation,
        groups: usize,
    ) -> Layer {
        Layer {
            groups,
            ..Layer::conv(name, input, out_channels, kernel, stride, padding, activation)
        }
    }

    pub fn maxpool(
        name: &str,
        input: LayerId,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: (usize, usize),
    ) -> Layer {
        Layer { inputs: vec![input], kernel, stride, padding, ..Layer::new(name, Op::MaxPool) }
    }

    pub fn avgpool(
        name: &str,
        input: LayerId,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: (usize, usize),
    ) -> Layer {
        Layer { inputs: vec![input], kernel, stride, padding, ..Layer::new(name, Op::AvgPool) }
    }

    pub fn add(name: &str, inputs: Vec<LayerId>) -> Layer {
        Layer { inputs, ..Layer::new(name, Op::Add) }
    }

    pub fn concat(name: &str, inputs: Vec<LayerId>) -> Layer {
        Layer { inputs, ..Layer::new(name, Op::Concat) }
    }

    pub fn flatten(name: &str, input: LayerId) -> Layer {
        Layer { inputs: vec![input], ..Layer::new(name, Op::Flatten) }
    }

    pub fn dense(name: &str, input: LayerId, units: usize, activation: Activation) -> Layer {
        Layer {
            inputs: vec![input],
            out_channels: units,
            activation,
            ..Layer::new(name, Op::Dense)
        }
    }
}
