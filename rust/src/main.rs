//! `pico` — CLI launcher for the PICO pipeline-inference framework.
//!
//! Every command flows through the [`pico::deploy`] facade: build a
//! deployment plan, persist it, simulate it, serve it.
//!
//! ```text
//! pico partition --model inceptionv3 [--d 5] [--dc-parts 1]
//! pico plan      --model vgg16 --device rpi:1.0x4 [--device tx2:2.2x2]
//!                [--scheme pico] [--t-lim 2.5] [--replicas auto|N]
//! pico plan save --out plan.json [... same flags as plan]
//! pico plan load --plan plan.json [--requests 64]
//! pico simulate  --model vgg16 --device rpi:1.0x8 [--scheme pico|lw|efl|ofl|ce|bfs]
//! pico serve     --model tinyvgg --artifacts artifacts [--requests 16]
//! pico zoo
//! pico --config path.json <command>
//! ```
//!
//! Flags may be given at most once; only `--device KIND:GHZxCOUNT`
//! repeats (one occurrence per device group, any mix of kinds).

use std::path::PathBuf;

use pico::config::{Config, DeviceConfig};
use pico::deploy::{Backend, DeploymentPlan, Replicas, ServeConfig};
use pico::graph::width;
use pico::util::{fmt_secs, Table};
use pico::{modelzoo, partition};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny std-only argument parser: up to two verbs, then `--key value`
/// pairs. Duplicate flags are an error (silently keeping the last one
/// hid typos); `--device` is the one repeatable flag.
struct Args {
    verbs: Vec<String>,
    kv: std::collections::HashMap<String, String>,
    devices: Vec<String>,
}

impl Args {
    fn parse() -> anyhow::Result<Args> {
        let mut it = std::env::args().skip(1);
        let mut verbs = Vec::new();
        let mut kv = std::collections::HashMap::new();
        let mut devices = Vec::new();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = it.next().unwrap_or_else(|| "true".into());
                if key == "device" {
                    devices.push(val);
                } else if kv.insert(key.to_string(), val).is_some() {
                    anyhow::bail!(
                        "duplicate flag --{key}: each flag may appear once (only --device repeats)"
                    );
                }
            } else if verbs.len() < 2 {
                verbs.push(a);
            } else {
                anyhow::bail!("unexpected argument {a:?}");
            }
        }
        Ok(Args { verbs, kv, devices })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(|s| s.as_str())
    }
}

/// `KIND:GHZxCOUNT`, e.g. `rpi:1.0x4`, `tx2:2.2x2` or `orin:2.0x1`
/// (kinds beyond rpi/tx2 become generic rpi-class cores named after
/// the kind).
fn parse_device(spec: &str) -> anyhow::Result<DeviceConfig> {
    let usage = || {
        anyhow::anyhow!("--device expects KIND:GHZxCOUNT, e.g. rpi:1.0x4 (got {spec:?})")
    };
    let (kind, rest) = spec.split_once(':').ok_or_else(usage)?;
    if kind.is_empty() {
        return Err(usage());
    }
    let (ghz, count) = rest.split_once('x').ok_or_else(usage)?;
    Ok(DeviceConfig { kind: kind.into(), ghz: ghz.parse()?, count: count.parse()? })
}

fn run() -> anyhow::Result<()> {
    let args = Args::parse()?;
    let mut cfg = match args.get("config") {
        Some(p) => Config::load(&PathBuf::from(p))?,
        None => Config::default(),
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    if let Some(d) = args.get("d") {
        cfg.diameter = d.parse()?;
    }
    if let Some(p) = args.get("dc-parts") {
        cfg.dc_parts = p.parse()?;
    }
    if let Some(t) = args.get("t-lim") {
        cfg.t_lim = Some(t.parse()?);
    }
    if let Some(n) = args.get("requests") {
        cfg.n_requests = n.parse()?;
    }
    if !args.devices.is_empty() {
        cfg.devices = args
            .devices
            .iter()
            .map(|s| parse_device(s))
            .collect::<anyhow::Result<Vec<_>>>()?;
    }
    let replicas = match args.get("replicas") {
        None => Replicas::Fixed(1),
        Some("auto") => Replicas::Auto,
        Some(n) => Replicas::Fixed(n.parse()?),
    };

    let verb = args.verbs.first().map(|s| s.as_str()).unwrap_or("");
    let subverb = args.verbs.get(1).map(|s| s.as_str());
    match (verb, subverb) {
        ("partition", None) => cmd_partition(&cfg),
        ("plan", None) => {
            let d = build_deployment(&cfg, &args, replicas)?;
            print!("{}", d.explain());
            Ok(())
        }
        ("plan", Some("save")) => {
            let d = build_deployment(&cfg, &args, replicas)?;
            let out = PathBuf::from(args.get("out").unwrap_or("plan.json"));
            d.save(&out)?;
            println!(
                "saved {} plan for {} ({} replicas, {} stages) to {}",
                d.scheme,
                d.model,
                d.replicas.len(),
                d.replicas.iter().map(|p| p.stages.len()).sum::<usize>(),
                out.display()
            );
            Ok(())
        }
        ("plan", Some("load")) => {
            let path = PathBuf::from(args.get("plan").unwrap_or("plan.json"));
            let d = DeploymentPlan::load(&path)?;
            print!("{}", d.explain());
            print_sim(&d, cfg.n_requests)
        }
        ("simulate", None) => {
            let d = build_deployment(&cfg, &args, replicas)?;
            print_sim(&d, cfg.n_requests)
        }
        ("serve", None) => cmd_serve(&cfg, args.get("artifacts").unwrap_or("artifacts")),
        ("zoo", None) => cmd_zoo(),
        other => anyhow::bail!(
            "unknown command {other:?}; try: partition | plan [save|load] | simulate | serve | zoo"
        ),
    }
}

fn build_deployment(
    cfg: &Config,
    args: &Args,
    replicas: Replicas,
) -> anyhow::Result<DeploymentPlan> {
    Ok(DeploymentPlan::builder()
        .config(cfg)
        .scheme(args.get("scheme").unwrap_or("pico"))
        .replicas(replicas)
        .build()?)
}

fn cmd_partition(cfg: &Config) -> anyhow::Result<()> {
    let g = pico::deploy::resolve_model(&cfg.model, std::path::Path::new("artifacts"))?;
    let r = if cfg.dc_parts > 1 {
        partition::partition_divide_conquer(&g, cfg.diameter, cfg.dc_parts, None)?
    } else {
        partition::partition(&g, cfg.diameter, None)?
    };
    println!(
        "model={} n={} (conv+pool {}) w={} -> {} pieces, F(G)={:.3e} FLOPs, {} states, {}",
        g.name,
        g.n_layers(),
        g.n_conv_pool(),
        width(&g),
        r.pieces.len(),
        r.max_redundancy,
        r.states,
        fmt_secs(r.elapsed.as_secs_f64()),
    );
    let mut t = Table::new(&["piece", "layers", "diameter", "halo rows", "redundancy FLOPs"]);
    for (k, p) in r.pieces.iter().enumerate() {
        let seg = pico::graph::Segment::from_ids(p.iter().copied());
        t.row(&[
            format!("{k}"),
            p.iter().map(|&i| g.layer(i).name.clone()).collect::<Vec<_>>().join(","),
            format!("{}", seg.diameter(&g)),
            format!("{}", pico::cost::halo_rows(&g, p)),
            format!("{:.3e}", pico::cost::piece_redundancy(&g, p, 2)),
        ]);
    }
    t.print();
    Ok(())
}

fn print_sim(d: &DeploymentPlan, n_requests: usize) -> anyhow::Result<()> {
    let report = d.simulate(n_requests)?;
    println!(
        "{} on {} x{}: throughput {:.3}/s period {} latency {} energy/task {:.2} J",
        report.scheme,
        d.model,
        d.cluster.len(),
        report.throughput,
        fmt_secs(report.period),
        fmt_secs(report.latency),
        report.energy_per_task()
    );
    let mut t = Table::new(&["device", "util %", "redu %", "mem MB", "energy J"]);
    for dm in &report.per_device {
        t.row(&[
            d.cluster.devices[dm.device].name.clone(),
            format!("{:.1}", dm.utilization * 100.0),
            format!("{:.1}", dm.redundancy * 100.0),
            format!("{:.1}", (dm.mem_model + dm.mem_feature) as f64 / 1e6),
            format!("{:.1}", dm.energy_j),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_serve(cfg: &Config, artifacts: &str) -> anyhow::Result<()> {
    let dir = PathBuf::from(artifacts);
    // PJRT executes the AOT plan (its tile shapes ARE the artifact set);
    // when artifacts are absent the same model serves on the native
    // backend with the planner run locally.
    let serve_cfg = ServeConfig { n_requests: cfg.n_requests, ..ServeConfig::default() };
    let report = match DeploymentPlan::from_artifacts(&dir, &cfg.model) {
        Ok(d) => {
            println!("backend: PJRT (AOT artifacts, plan from plan.json)");
            d.serve(&Backend::Pjrt { dir: dir.clone() }, &serve_cfg)?
        }
        Err(e) => {
            println!("backend: native (PJRT unavailable: {e})");
            let g = modelzoo::load_tiny(&dir, &cfg.model)
                .map_err(|e| anyhow::anyhow!("serve needs a tiny e2e model spec: {e}"))?;
            let d = DeploymentPlan::builder().graph(g).config(cfg).artifacts_dir(&dir).build()?;
            d.serve(&Backend::Native { seed: 0 }, &serve_cfg)?
        }
    };
    println!(
        "served {} requests: virtual throughput {:.2}/s period {} mean latency {} (wall {:.2}s)",
        report.responses.len(),
        report.throughput,
        fmt_secs(report.period),
        fmt_secs(report.mean_latency),
        report.wall_secs
    );
    Ok(())
}

fn cmd_zoo() -> anyhow::Result<()> {
    let mut t = Table::new(&["model", "layers", "conv+pool n", "width w", "GFLOPs", "params MB"]);
    for name in [
        "vgg16", "yolov2", "resnet34", "inceptionv3", "squeezenet", "mobilenetv3", "nasnetlarge",
    ] {
        let g = modelzoo::by_name(name)?;
        let params: usize = (0..g.n_layers()).map(|i| pico::sim::layer_param_bytes(&g, i)).sum();
        t.row(&[
            name.into(),
            format!("{}", g.n_layers()),
            format!("{}", g.n_conv_pool()),
            format!("{}", width(&g)),
            format!("{:.2}", pico::cost::total_flops(&g) / 1e9),
            format!("{:.1}", params as f64 / 1e6),
        ]);
    }
    t.print();
    Ok(())
}
