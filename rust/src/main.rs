//! `pico` — CLI launcher for the PICO pipeline-inference framework.
//!
//! ```text
//! pico partition --model inceptionv3 [--d 5] [--dc-parts 1]
//! pico plan      --model vgg16 --rpi 1.0x4 [--tx2 2.2x2] [--t-lim 2.5]
//! pico simulate  --model vgg16 --rpi 1.0x8 [--scheme pico|lw|efl|ofl|ce]
//! pico serve     --model tinyvgg --artifacts artifacts [--requests 16]
//! pico zoo
//! pico --config path.json <command>
//! ```

use std::path::PathBuf;

use pico::cluster::Cluster;
use pico::config::{Config, DeviceConfig};
use pico::coordinator::{self, NativeCompute, PjrtCompute};
use pico::graph::width;
use pico::runtime::{Engine, PipelineArtifacts, Tensor};
use pico::util::{fmt_secs, Rng, Table};
use pico::{baselines, modelzoo, partition, pipeline, sim};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Tiny std-only argument parser: `--key value` pairs after a verb.
struct Args {
    verb: String,
    kv: std::collections::HashMap<String, String>,
}

impl Args {
    fn parse() -> anyhow::Result<Args> {
        let mut it = std::env::args().skip(1).peekable();
        let mut verb = String::new();
        let mut kv = std::collections::HashMap::new();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                let val = it.next().unwrap_or_else(|| "true".into());
                kv.insert(key.to_string(), val);
            } else if verb.is_empty() {
                verb = a;
            } else {
                anyhow::bail!("unexpected argument {a:?}");
            }
        }
        Ok(Args { verb, kv })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.kv.get(key).map(|s| s.as_str())
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::parse()?;
    let mut cfg = match args.get("config") {
        Some(p) => Config::load(&PathBuf::from(p))?,
        None => Config::default(),
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    if let Some(d) = args.get("d") {
        cfg.diameter = d.parse()?;
    }
    if let Some(p) = args.get("dc-parts") {
        cfg.dc_parts = p.parse()?;
    }
    if let Some(t) = args.get("t-lim") {
        cfg.t_lim = Some(t.parse()?);
    }
    if let Some(n) = args.get("requests") {
        cfg.n_requests = n.parse()?;
    }
    // --rpi 1.0x4 / --tx2 2.2x2 cluster spec (repeatable via config file).
    let mut devices = Vec::new();
    for kind in ["rpi", "tx2"] {
        if let Some(spec) = args.get(kind) {
            let (ghz, count) = spec
                .split_once('x')
                .ok_or_else(|| anyhow::anyhow!("--{kind} expects GHZxCOUNT, e.g. 1.0x4"))?;
            devices.push(DeviceConfig {
                kind: kind.into(),
                ghz: ghz.parse()?,
                count: count.parse()?,
            });
        }
    }
    if !devices.is_empty() {
        cfg.devices = devices;
    }

    match args.verb.as_str() {
        "partition" => cmd_partition(&cfg),
        "plan" => cmd_plan(&cfg),
        "simulate" => cmd_simulate(&cfg, args.get("scheme").unwrap_or("pico")),
        "serve" => cmd_serve(&cfg, args.get("artifacts").unwrap_or("artifacts")),
        "zoo" => cmd_zoo(),
        other => anyhow::bail!(
            "unknown command {other:?}; try: partition | plan | simulate | serve | zoo"
        ),
    }
}

fn load_model(cfg: &Config) -> anyhow::Result<pico::graph::ModelGraph> {
    if cfg.model.ends_with(".json") {
        pico::graph::ModelGraph::load(&PathBuf::from(&cfg.model))
    } else if let Ok(g) = modelzoo::by_name(&cfg.model) {
        Ok(g)
    } else {
        modelzoo::load_tiny(&PathBuf::from("artifacts"), &cfg.model)
    }
}

fn cmd_partition(cfg: &Config) -> anyhow::Result<()> {
    let g = load_model(cfg)?;
    let r = if cfg.dc_parts > 1 {
        partition::partition_divide_conquer(&g, cfg.diameter, cfg.dc_parts, None)?
    } else {
        partition::partition(&g, cfg.diameter, None)?
    };
    println!(
        "model={} n={} (conv+pool {}) w={} -> {} pieces, F(G)={:.3e} FLOPs, {} states, {}",
        g.name,
        g.n_layers(),
        g.n_conv_pool(),
        width(&g),
        r.pieces.len(),
        r.max_redundancy,
        r.states,
        fmt_secs(r.elapsed.as_secs_f64()),
    );
    let mut t = Table::new(&["piece", "layers", "diameter", "halo rows", "redundancy FLOPs"]);
    for (k, p) in r.pieces.iter().enumerate() {
        let seg = pico::graph::Segment::from_ids(p.iter().copied());
        t.row(&[
            format!("{k}"),
            p.iter().map(|&i| g.layer(i).name.clone()).collect::<Vec<_>>().join(","),
            format!("{}", seg.diameter(&g)),
            format!("{}", pico::cost::halo_rows(&g, p)),
            format!("{:.3e}", pico::cost::piece_redundancy(&g, p, 2)),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_plan(cfg: &Config) -> anyhow::Result<()> {
    let g = load_model(cfg)?;
    let cluster = cfg.cluster();
    let pieces = partition::partition(&g, cfg.diameter, None)?.pieces;
    let plan = pipeline::plan(&g, &pieces, &cluster, cfg.t_lim_or_inf())?;
    let cost = plan.cost(&g, &cluster);
    println!(
        "model={} cluster={} devices; {} stages; period {} latency {} throughput {:.2}/s",
        g.name,
        cluster.len(),
        plan.stages.len(),
        fmt_secs(cost.period),
        fmt_secs(cost.latency),
        1.0 / cost.period
    );
    let mut t = Table::new(&["stage", "pieces", "layers", "devices", "T_comp", "T_comm", "T"]);
    for (k, s) in plan.stages.iter().enumerate() {
        let sc = &cost.stage_costs[k];
        t.row(&[
            format!("{k}"),
            format!("{}..={}", s.pieces.0, s.pieces.1),
            format!("{}", s.layers.len()),
            format!(
                "{}",
                s.devices
                    .iter()
                    .map(|&d| cluster.devices[d].name.clone())
                    .collect::<Vec<_>>()
                    .join("+")
            ),
            fmt_secs(sc.t_comp_stage),
            fmt_secs(sc.t_comm_stage),
            fmt_secs(sc.total),
        ]);
    }
    t.print();
    println!("{}", plan.to_json(&g));
    Ok(())
}

fn cmd_simulate(cfg: &Config, scheme: &str) -> anyhow::Result<()> {
    let g = load_model(cfg)?;
    let cluster = cfg.cluster();
    let n = cfg.n_requests;
    let report = match scheme {
        "pico" => {
            let pieces = partition::partition(&g, cfg.diameter, None)?.pieces;
            let plan = pipeline::plan(&g, &pieces, &cluster, cfg.t_lim_or_inf())?;
            sim::simulate_pipeline(&g, &cluster, &plan, n)
        }
        "lw" => sim::simulate_sync(&g, &cluster, &baselines::layer_wise(&g, &cluster), n),
        "efl" => sim::simulate_sync(&g, &cluster, &baselines::early_fused(&g, &cluster, 2), n),
        "ofl" => {
            let pieces = partition::partition(&g, cfg.diameter, None)?.pieces;
            sim::simulate_sync(&g, &cluster, &baselines::optimal_fused(&g, &pieces, &cluster), n)
        }
        "ce" => sim::simulate_sync(&g, &cluster, &baselines::coedge(&g, &cluster), n),
        other => anyhow::bail!("unknown scheme {other:?} (pico|lw|efl|ofl|ce)"),
    };
    println!(
        "{} on {} x{}: throughput {:.3}/s period {} latency {} energy/task {:.2} J",
        report.scheme,
        g.name,
        cluster.len(),
        report.throughput,
        fmt_secs(report.period),
        fmt_secs(report.latency),
        report.energy_per_task()
    );
    let mut t = Table::new(&["device", "util %", "redu %", "mem MB", "energy J"]);
    for d in &report.per_device {
        t.row(&[
            cluster.devices[d.device].name.clone(),
            format!("{:.1}", d.utilization * 100.0),
            format!("{:.1}", d.redundancy * 100.0),
            format!("{:.1}", (d.mem_model + d.mem_feature) as f64 / 1e6),
            format!("{:.1}", d.energy_j),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_serve(cfg: &Config, artifacts: &str) -> anyhow::Result<()> {
    let dir = PathBuf::from(artifacts);
    let g = modelzoo::load_tiny(&dir, &cfg.model)
        .map_err(|e| anyhow::anyhow!("serve needs a tiny e2e model with artifacts: {e}"))?;
    let (c, h, w) = g.input_shape;
    let mut rng = Rng::new(42);
    let requests: Vec<coordinator::Request> = (0..cfg.n_requests as u64)
        .map(|id| coordinator::Request {
            id,
            input: Tensor::new(vec![c, h, w], (0..c * h * w).map(|_| rng.normal() as f32).collect()),
            t_submit: 0.0,
        })
        .collect();
    // PJRT executes the AOT plan (its tile shapes ARE the artifact set);
    // any other plan/cluster runs on the native backend.
    let report = match try_pjrt(&dir, &cfg.model, &g, requests.clone()) {
        Ok(r) => {
            println!("backend: PJRT (AOT artifacts, plan from plan.json)");
            r
        }
        Err(e) => {
            println!("backend: native (PJRT unavailable: {e})");
            let cluster = cfg.cluster();
            let pieces = partition::partition(&g, cfg.diameter, None)?.pieces;
            let plan = pipeline::plan(&g, &pieces, &cluster, cfg.t_lim_or_inf())?;
            let compute = NativeCompute {
                weights: pico::runtime::executor::model_weights(&g, 0),
            };
            coordinator::serve(&g, &plan, &cluster, &compute, requests)?
        }
    };
    println!(
        "served {} requests: virtual throughput {:.2}/s period {} mean latency {} (wall {:.2}s)",
        report.responses.len(),
        report.throughput,
        fmt_secs(report.period),
        fmt_secs(report.mean_latency),
        report.wall_secs
    );
    Ok(())
}

fn try_pjrt(
    dir: &std::path::Path,
    model: &str,
    g: &pico::graph::ModelGraph,
    requests: Vec<coordinator::Request>,
) -> anyhow::Result<coordinator::ServeReport> {
    let engine = std::sync::Arc::new(Engine::cpu()?);
    let artifacts = std::sync::Arc::new(PipelineArtifacts::load(dir, model)?);
    let (plan, n_devices) = pipeline::PipelinePlan::from_artifact_plan(g, &artifacts.plan)?;
    let cluster = Cluster::homogeneous_rpi(n_devices, 1.0);
    let compute = PjrtCompute { engine, artifacts };
    coordinator::serve(g, &plan, &cluster, &compute, requests)
}

fn cmd_zoo() -> anyhow::Result<()> {
    let mut t = Table::new(&["model", "layers", "conv+pool n", "width w", "GFLOPs", "params MB"]);
    for name in [
        "vgg16", "yolov2", "resnet34", "inceptionv3", "squeezenet", "mobilenetv3", "nasnetlarge",
    ] {
        let g = modelzoo::by_name(name)?;
        let params: usize = (0..g.n_layers()).map(|i| sim::layer_param_bytes(&g, i)).sum();
        t.row(&[
            name.into(),
            format!("{}", g.n_layers()),
            format!("{}", g.n_conv_pool()),
            format!("{}", width(&g)),
            format!("{:.2}", pico::cost::total_flops(&g) / 1e9),
            format!("{:.1}", params as f64 / 1e6),
        ]);
    }
    t.print();
    Ok(())
}
