//! Heterogeneous device cluster `D` (paper §3.1.2) — the simulated
//! substitute for the paper's 8×Raspberry-Pi-4B + 2×Jetson-TX2-NX testbed.
//!
//! The paper's cost model consumes devices only through their computing
//! capacity ϑ(d_k) (FLOPS), the regression coefficient α_k (Eq. 7) and a
//! uniform WLAN bandwidth b, so a simulated device is exactly that tuple
//! plus the power/memory attributes used by the §6.3–6.4 experiments.

use crate::error::PicoError;
use crate::json::{obj, Value};
use crate::util::Rng;

/// One mobile device `d_k`.
#[derive(Debug, Clone)]
pub struct Device {
    pub id: usize,
    pub name: String,
    /// ϑ(d_k): effective floating-point throughput (FLOP/s).
    pub flops: f64,
    /// α_k: measured-vs-model regression coefficient (Eq. 7); 1.0 = ideal.
    pub alpha: f64,
    /// Power draw while executing (W) — Monsoon HVPM substitute.
    pub active_power_w: f64,
    /// Power draw while idle in the pipeline (W).
    pub standby_power_w: f64,
    /// Onboard memory (bytes); exceeding it forces swap (paper §6.3.2).
    pub mem_bytes: usize,
}

impl Device {
    /// Raspberry-Pi 4B, one Cortex-A72 core at `ghz` (paper caps CPU
    /// frequency with cGroup to emulate heterogeneity). Effective FLOPS
    /// calibrated at ~2 flop/cycle single-core NEON fp32.
    pub fn rpi(id: usize, ghz: f64) -> Device {
        Device {
            id,
            name: format!("Rpi@{ghz:.1}"),
            flops: ghz * 1e9 * 2.0,
            alpha: 1.0,
            active_power_w: 3.4 * (0.5 + ghz / 3.0), // freq-scaled core power
            standby_power_w: 1.9,
            mem_bytes: 2 * 1024 * 1024 * 1024, // 2 GB LPDDR2
        }
    }

    /// Nvidia Jetson TX2 NX CPU (Denver/A57 class) at `ghz`.
    pub fn tx2(id: usize, ghz: f64) -> Device {
        Device {
            id,
            name: format!("NX@{ghz:.1}"),
            flops: ghz * 1e9 * 4.0, // wider core: ~2x rpi per GHz
            alpha: 1.0,
            active_power_w: 7.5,
            standby_power_w: 3.0,
            mem_bytes: 4 * 1024 * 1024 * 1024,
        }
    }

    /// Any other device kind: an rpi-class ARM core model (2 flop/cycle
    /// NEON fp32, frequency-scaled power) with the kind preserved in
    /// the name — so heterogeneous clusters beyond the paper's two
    /// device models stay expressible from configs and the CLI's
    /// `--device KIND:GHZxCOUNT` flag.
    pub fn generic(id: usize, kind: &str, ghz: f64) -> Device {
        Device {
            id,
            name: format!("{kind}@{ghz:.1}"),
            flops: ghz * 1e9 * 2.0,
            alpha: 1.0,
            active_power_w: 3.4 * (0.5 + ghz / 3.0),
            standby_power_w: 1.9,
            mem_bytes: 2 * 1024 * 1024 * 1024,
        }
    }

    /// Eq. (7): computation time for `flops` work on this device.
    pub fn t_comp(&self, flops: f64) -> f64 {
        self.alpha * flops / self.flops
    }
}

/// Uniform-bandwidth WLAN (paper assumption §3.1.2: devices share one
/// Wi-Fi AP; 50 Mbps in the testbed).
#[derive(Debug, Clone, Copy)]
pub struct Network {
    /// b: bandwidth between any device pair (bytes/s).
    pub bandwidth_bps: f64,
    /// Per-message latency floor (s) — Wi-Fi MAC + Gloo overhead.
    pub latency_s: f64,
}

impl Network {
    /// 50 Mbps shared AP; the per-message floor models Wi-Fi MAC
    /// contention + Gloo rendezvous (the paper's §6.3 observation that
    /// per-layer schemes drown in round-trips at WLAN latencies).
    pub fn wifi_50mbps() -> Network {
        Network { bandwidth_bps: 50e6 / 8.0, latency_s: 8e-3 }
    }

    /// Eq. (9): transfer time for `bytes` between two devices.
    pub fn t_comm(&self, bytes: usize) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }
}

/// A cluster: devices + shared network.
#[derive(Debug, Clone)]
pub struct Cluster {
    pub devices: Vec<Device>,
    pub network: Network,
}

impl Cluster {
    pub fn new(devices: Vec<Device>, network: Network) -> Cluster {
        Cluster { devices, network }
    }

    /// Homogeneous Raspberry-Pi cluster (Figs. 12–15 setup).
    pub fn homogeneous_rpi(n: usize, ghz: f64) -> Cluster {
        Cluster::new((0..n).map(|i| Device::rpi(i, ghz)).collect(), Network::wifi_50mbps())
    }

    /// The paper's heterogeneous testbed (§6.1 + Table 5): 2× TX2 NX at
    /// 2.2 GHz and 6× Rpi at {1.5, 1.5, 1.2, 1.2, 0.8, 0.8} GHz.
    pub fn paper_heterogeneous() -> Cluster {
        let mut devices = vec![Device::tx2(0, 2.2), Device::tx2(1, 2.2)];
        for (i, ghz) in [1.5, 1.5, 1.2, 1.2, 0.8, 0.8].iter().enumerate() {
            devices.push(Device::rpi(2 + i, *ghz));
        }
        Cluster::new(devices, Network::wifi_50mbps())
    }

    /// Random heterogeneous cluster for property tests / sweeps.
    pub fn random(n: usize, rng: &mut Rng) -> Cluster {
        let freqs = [0.6, 0.8, 1.0, 1.2, 1.5];
        let devices = (0..n).map(|i| Device::rpi(i, freqs[rng.below(freqs.len())])).collect();
        Cluster::new(devices, Network::wifi_50mbps())
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Eq. (14): the homogenised twin cluster D′ — same size, every
    /// device gets the average capacity. Algorithm 2 plans against this.
    pub fn homogenized(&self) -> Cluster {
        let avg_flops = self.devices.iter().map(|d| d.flops).sum::<f64>() / self.len() as f64;
        let avg_alpha = self.devices.iter().map(|d| d.alpha).sum::<f64>() / self.len() as f64;
        let devices = self
            .devices
            .iter()
            .map(|d| Device { flops: avg_flops, alpha: avg_alpha, ..d.clone() })
            .collect();
        Cluster { devices, network: self.network }
    }

    /// Total capacity (FLOP/s) of the cluster.
    pub fn total_flops(&self) -> f64 {
        self.devices.iter().map(|d| d.flops).sum()
    }

    /// Partition device indices into `r` capacity-balanced groups
    /// (greedy LPT: strongest device to the currently weakest group) —
    /// the replica partitioner behind
    /// [`crate::pipeline::plan_replicated`]. Balanced groups keep the
    /// replica periods close, which is what lets R replicas deliver
    /// ~R× the throughput of one.
    pub fn partition_capacity(&self, r: usize) -> Vec<Vec<usize>> {
        assert!(r >= 1 && r <= self.len(), "need 1..=n_devices groups, got {r}");
        let cap = |i: usize| self.devices[i].flops / self.devices[i].alpha;
        let mut idx: Vec<usize> = (0..self.len()).collect();
        idx.sort_by(|&a, &b| cap(b).total_cmp(&cap(a)));
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); r];
        let mut load = vec![0.0f64; r];
        for i in idx {
            let mut g = 0;
            for k in 1..r {
                if load[k] < load[g] {
                    g = k;
                }
            }
            groups[g].push(i);
            load[g] += cap(i);
        }
        for g in &mut groups {
            g.sort_unstable();
        }
        groups
    }

    /// Serialize the full device tuples (not just kind+GHz shorthand):
    /// a plan artifact must reproduce the exact capacities it was
    /// computed against, wherever it is re-loaded.
    pub fn to_json(&self) -> Value {
        let devices: Vec<Value> = self
            .devices
            .iter()
            .map(|d| {
                obj(vec![
                    ("id", d.id.into()),
                    ("name", d.name.as_str().into()),
                    ("flops", d.flops.into()),
                    ("alpha", d.alpha.into()),
                    ("active_power_w", d.active_power_w.into()),
                    ("standby_power_w", d.standby_power_w.into()),
                    ("mem_bytes", d.mem_bytes.into()),
                ])
            })
            .collect();
        obj(vec![
            ("devices", Value::Arr(devices)),
            (
                "network",
                obj(vec![
                    ("bandwidth_bps", self.network.bandwidth_bps.into()),
                    ("latency_s", self.network.latency_s.into()),
                ]),
            ),
        ])
    }

    /// Inverse of [`Cluster::to_json`].
    pub fn from_json(v: &Value) -> Result<Cluster, PicoError> {
        let arr = v
            .get("devices")
            .as_arr()
            .ok_or_else(|| PicoError::InvalidCluster("missing devices array".into()))?;
        if arr.is_empty() {
            return Err(PicoError::InvalidCluster("cluster has no devices".into()));
        }
        let mut devices = Vec::with_capacity(arr.len());
        for (i, dv) in arr.iter().enumerate() {
            let num = |key: &str| -> Result<f64, PicoError> {
                dv.get(key).as_f64().ok_or_else(|| {
                    PicoError::InvalidCluster(format!("device {i}: missing field {key:?}"))
                })
            };
            devices.push(Device {
                id: dv.get("id").as_usize().unwrap_or(i),
                name: dv.get("name").as_str().unwrap_or("device").to_string(),
                flops: num("flops")?,
                alpha: num("alpha")?,
                active_power_w: num("active_power_w")?,
                standby_power_w: num("standby_power_w")?,
                mem_bytes: dv.get("mem_bytes").as_usize().unwrap_or(0),
            });
        }
        let nw = v.get("network");
        let network = Network {
            bandwidth_bps: nw
                .get("bandwidth_bps")
                .as_f64()
                .ok_or_else(|| PicoError::InvalidCluster("missing network.bandwidth_bps".into()))?,
            latency_s: nw
                .get("latency_s")
                .as_f64()
                .ok_or_else(|| PicoError::InvalidCluster("missing network.latency_s".into()))?,
        };
        Ok(Cluster::new(devices, network))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rpi_scales_with_freq() {
        let fast = Device::rpi(0, 1.5);
        let slow = Device::rpi(1, 0.8);
        assert!(fast.flops > slow.flops);
        assert!((fast.flops / slow.flops - 1.5 / 0.8).abs() < 1e-9);
        // t_comp inversely proportional to capacity
        assert!(fast.t_comp(1e9) < slow.t_comp(1e9));
    }

    #[test]
    fn network_cost_linear() {
        let n = Network::wifi_50mbps();
        let t1 = n.t_comm(1_000_000);
        let t2 = n.t_comm(2_000_000);
        assert!(t2 > t1);
        assert!((t2 - t1 - 1_000_000.0 / n.bandwidth_bps).abs() < 1e-12);
    }

    #[test]
    fn homogenized_preserves_total_capacity() {
        let c = Cluster::paper_heterogeneous();
        let h = c.homogenized();
        assert_eq!(h.len(), c.len());
        assert!((h.total_flops() - c.total_flops()).abs() < 1.0);
        let first = h.devices[0].flops;
        assert!(h.devices.iter().all(|d| (d.flops - first).abs() < 1e-6));
    }

    #[test]
    fn partition_capacity_balances_groups() {
        let c = Cluster::paper_heterogeneous();
        let groups = c.partition_capacity(2);
        assert_eq!(groups.len(), 2);
        // every device in exactly one group
        let mut all: Vec<usize> = groups.iter().flatten().copied().collect();
        all.sort();
        assert_eq!(all, (0..c.len()).collect::<Vec<_>>());
        // the two TX2s must not land in the same group (LPT balance)
        let cap = |g: &Vec<usize>| g.iter().map(|&i| c.devices[i].flops).sum::<f64>();
        let (a, b) = (cap(&groups[0]), cap(&groups[1]));
        assert!((a - b).abs() / a.max(b) < 0.35, "unbalanced: {a} vs {b}");
        // degenerate splits
        assert_eq!(c.partition_capacity(1), vec![(0..8).collect::<Vec<usize>>()]);
        assert_eq!(c.partition_capacity(8).iter().filter(|g| g.len() == 1).count(), 8);
    }

    #[test]
    fn paper_cluster_composition() {
        let c = Cluster::paper_heterogeneous();
        assert_eq!(c.len(), 8);
        assert_eq!(c.devices.iter().filter(|d| d.name.starts_with("NX")).count(), 2);
    }
}
