//! EFL and OFL — fused-layer schemes.
//!
//! EFL (DeepThings [5]): fuse the early conv stack (through the
//! `fuse_pools`-th pooling layer), feature-split it across all devices,
//! then run the rest of the model on a single device. OFL (AOFL [6]):
//! choose fusion boundaries by DP so the sum of group costs is minimal —
//! all devices execute every group, synchronising between groups.

use std::sync::Arc;

use super::{SyncGroup, SyncSchedule};
use crate::cluster::{Cluster, Device};
use crate::cost::oracle::{CostOracle, PieceMeta};
use crate::cost::stage_cost;
use crate::graph::{ModelGraph, Op};
use crate::partition::PieceChain;

/// EFL: fuse everything up to (and including) the `fuse_pools`-th pool
/// layer across all devices; the tail runs on device 0. DeepThings fuses
/// "the first few layers"; two pool levels is its canonical setting.
pub fn early_fused(g: &ModelGraph, cluster: &Cluster, fuse_pools: usize) -> SyncSchedule {
    let all: Vec<usize> = (0..cluster.len()).collect();
    let mut cut = g.n_layers();
    let mut pools = 0;
    for id in 0..g.n_layers() {
        if matches!(g.layer(id).op, Op::MaxPool | Op::AvgPool) {
            pools += 1;
            if pools == fuse_pools {
                cut = id + 1;
                break;
            }
        }
    }
    let head: Vec<usize> = (0..cut).filter(|&i| g.layer(i).op != Op::Input).collect();
    let tail: Vec<usize> = (cut..g.n_layers()).collect();
    let mut groups = vec![SyncGroup { layers: head, devices: all, halo_sync: false }];
    if !tail.is_empty() {
        groups.push(SyncGroup { layers: tail, devices: vec![0], halo_sync: false });
    }
    SyncSchedule { name: "EFL".into(), groups }
}

/// OFL: DP over the piece chain choosing fusion boundaries that minimise
/// the summed group cost (computation + per-group sync), every group on
/// all devices. `pieces` usually comes from Algorithm 1 so OFL handles
/// DAG models exactly like the paper's AOFL-at-block-level comparison.
pub fn optimal_fused(g: &ModelGraph, pieces: &PieceChain, cluster: &Cluster) -> SyncSchedule {
    let meta = Arc::new(PieceMeta::build(g, pieces));
    optimal_fused_with_meta(g, pieces, &meta, cluster)
}

/// [`optimal_fused`] against pre-built piece aggregates: the O(L²)
/// group-cost table is answered by the interval cost oracle (one
/// heterogeneous roster over the whole cluster) instead of per-query
/// `stage_cost` graph walks. Falls back to the walk — same results —
/// when the chain fails the oracle's structural validation.
pub fn optimal_fused_with_meta(
    g: &ModelGraph,
    pieces: &PieceChain,
    meta: &Arc<PieceMeta>,
    cluster: &Cluster,
) -> SyncSchedule {
    let all: Vec<usize> = (0..cluster.len()).collect();
    let devs: Vec<&Device> = cluster.devices.iter().collect();
    let l = pieces.len();
    let mut oracle = if meta.exact() {
        Some(CostOracle::new(g, meta.clone(), cluster.devices.clone(), cluster.network))
    } else {
        None
    };
    // cost[i][j]: executing pieces i..=j as one fused group on all devices
    let mut group_cost = |i: usize, j: usize| -> f64 {
        match oracle.as_mut() {
            Some(o) => o.interval_cost(i, j),
            None => stage_cost(g, &meta.segment(i, j), &devs, &cluster.network).total,
        }
    };
    // DP: best[j] = min over i<=j of best[i-1] + cost(i, j)
    let mut best = vec![f64::INFINITY; l + 1];
    let mut back = vec![0usize; l + 1];
    best[0] = 0.0;
    for j in 1..=l {
        for i in 1..=j {
            let c = best[i - 1] + group_cost(i - 1, j - 1);
            if c < best[j] {
                best[j] = c;
                back[j] = i - 1;
            }
        }
    }
    let mut bounds = Vec::new();
    let mut j = l;
    while j > 0 {
        bounds.push((back[j], j - 1));
        j = back[j];
    }
    bounds.reverse();
    let groups = bounds
        .into_iter()
        .map(|(i, jj)| SyncGroup {
            layers: meta.segment(i, jj),
            devices: all.clone(),
            halo_sync: false,
        })
        .collect();
    SyncSchedule { name: "OFL".into(), groups }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modelzoo;
    use crate::partition;

    #[test]
    fn efl_splits_head_and_tail() {
        let g = modelzoo::vgg16();
        let c = Cluster::homogeneous_rpi(4, 1.0);
        let s = early_fused(&g, &c, 2);
        assert_eq!(s.groups.len(), 2);
        assert_eq!(s.groups[0].devices.len(), 4);
        assert_eq!(s.groups[1].devices, vec![0]);
        // head ends at pool2
        let pool2 = g.by_name("pool2").unwrap();
        assert!(s.groups[0].layers.contains(&pool2));
        assert!(!s.groups[0].layers.iter().any(|&i| i > pool2));
    }

    #[test]
    fn ofl_groups_tile_the_model() {
        let g = modelzoo::vgg16();
        let c = Cluster::homogeneous_rpi(4, 1.0);
        let pieces = partition::partition(&g, 5, None).unwrap().pieces;
        let s = optimal_fused(&g, &pieces, &c);
        let mut covered: Vec<usize> = s.groups.iter().flat_map(|gr| gr.layers.clone()).collect();
        covered.sort();
        let expect: Vec<usize> = (0..g.n_layers())
            .filter(|&i| !pieces.is_empty() && i != 0 || pieces[0].contains(&0))
            .collect();
        // groups cover every layer exactly once (input layer belongs to
        // the first piece if Algorithm 1 placed it there)
        let mut all_pieces: Vec<usize> = pieces.iter().flatten().copied().collect();
        all_pieces.sort();
        assert_eq!(covered, all_pieces);
        let _ = expect;
        assert!(s.groups.len() > 1, "OFL should choose several groups on VGG16");
    }

    #[test]
    fn ofl_not_worse_than_single_fused_group() {
        let g = modelzoo::vgg16();
        let c = Cluster::homogeneous_rpi(8, 1.0);
        let pieces = partition::partition(&g, 5, None).unwrap().pieces;
        let ofl = optimal_fused(&g, &pieces, &c);
        let devs: Vec<&Device> = c.devices.iter().collect();
        let total_ofl: f64 = ofl
            .groups
            .iter()
            .map(|gr| stage_cost(&g, &gr.layers, &devs, &c.network).total)
            .sum();
        let mut whole: Vec<usize> = pieces.iter().flatten().copied().collect();
        whole.sort();
        let single = stage_cost(&g, &whole, &devs, &c.network).total;
        assert!(total_ofl <= single + 1e-9, "OFL {total_ofl} vs single fused {single}");
    }
}
