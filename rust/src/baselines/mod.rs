//! The compared parallelisation schemes of §6.1:
//!
//! * **LW** (layer-wise, MoDNN): every layer split over all devices,
//!   features gathered+re-scattered between layers.
//! * **EFL** (early-fused-layer, DeepThings): the first few conv layers
//!   fused and feature-split over all devices, the remainder on one.
//! * **OFL** (optimal-fused-layer, AOFL): DP-chosen fusion boundaries;
//!   every fused group runs on all devices with a sync between groups.
//! * **CE** (CoEdge): layer-wise with a *dynamic* device count per layer
//!   and halo-only neighbour synchronisation.
//! * **BFS**: exhaustive search over pipeline configurations — the
//!   optimality reference of §6.5 (exponential; bounded by a budget).
//!
//! LW/EFL/OFL/CE produce a [`SyncSchedule`] (groups executed in sequence
//! for every inference — no pipelining); PICO and BFS produce
//! [`crate::pipeline::PipelinePlan`]s. The simulator consumes either.

mod bfs;
mod coedge;
mod fused;
mod layerwise;

pub use bfs::{bfs_optimal, BfsResult};
pub use coedge::{coedge, halo_fraction};
pub use fused::{early_fused, optimal_fused, optimal_fused_with_meta};
pub use layerwise::layer_wise;

use crate::graph::LayerId;
use crate::pipeline::{ExecutionMode, PipelinePlan, Stage};

/// One synchronously executed group: `layers` fused (no communication
/// inside), feature-split across `device_count` devices; after the group
/// completes, outputs are gathered (or halo-exchanged for CoEdge).
#[derive(Debug, Clone)]
pub struct SyncGroup {
    pub layers: Vec<LayerId>,
    /// Cluster device indices executing this group.
    pub devices: Vec<usize>,
    /// CoEdge-style neighbour sync: only halo rows are exchanged instead
    /// of full gather+scatter.
    pub halo_sync: bool,
}

/// A non-pipelined schedule: groups run in sequence per inference.
#[derive(Debug, Clone)]
pub struct SyncSchedule {
    pub name: String,
    pub groups: Vec<SyncGroup>,
}

impl SyncSchedule {
    /// Lift the schedule into the unified plan representation (one
    /// [`ExecutionMode::Synchronous`] stage per group) so every scheme
    /// flows through [`crate::deploy::Scheme::plan`].
    pub fn to_plan(&self) -> PipelinePlan {
        let stages = self
            .groups
            .iter()
            .enumerate()
            .map(|(k, gr)| Stage {
                pieces: (k, k),
                layers: gr.layers.clone(),
                devices: gr.devices.clone(),
                halo_sync: gr.halo_sync,
            })
            .collect();
        PipelinePlan { stages, execution: ExecutionMode::Synchronous }
    }

    /// Inverse of [`SyncSchedule::to_plan`], used by the simulator to
    /// cost a synchronous plan loaded from an artifact.
    pub fn from_plan(name: &str, plan: &PipelinePlan) -> SyncSchedule {
        debug_assert_eq!(plan.execution, ExecutionMode::Synchronous);
        SyncSchedule {
            name: name.to_string(),
            groups: plan
                .stages
                .iter()
                .map(|s| SyncGroup {
                    layers: s.layers.clone(),
                    devices: s.devices.clone(),
                    halo_sync: s.halo_sync,
                })
                .collect(),
        }
    }
}
