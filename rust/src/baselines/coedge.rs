//! CE — CoEdge (Zeng et al. [22]): layer-wise execution with
//! (1) workload partition proportional to device capability,
//! (2) halo-only synchronisation with neighbour devices, and
//! (3) a *dynamic* number of working devices per layer — small feature
//! maps run on fewer (faster) devices to dodge communication overhead.

use super::{SyncGroup, SyncSchedule};
use crate::cluster::{Cluster, Device};
use crate::cost::stage_cost;
use crate::graph::{ModelGraph, Op};

/// Build the CoEdge schedule: for every layer pick the device subset
/// (fastest-first prefix) minimising that layer's halo-sync cost.
pub fn coedge(g: &ModelGraph, cluster: &Cluster) -> SyncSchedule {
    // Fastest-first device order; prefixes of it are the candidate sets.
    let mut order: Vec<usize> = (0..cluster.len()).collect();
    order.sort_by(|&a, &b| {
        cluster.devices[b].flops.partial_cmp(&cluster.devices[a].flops).unwrap()
    });
    let mut groups = Vec::new();
    for id in 0..g.n_layers() {
        if g.layer(id).op == Op::Input {
            continue;
        }
        let mut best_cost = f64::INFINITY;
        let mut best_m = 1;
        for m in 1..=order.len() {
            let devs: Vec<&Device> = order[..m].iter().map(|&i| &cluster.devices[i]).collect();
            let mut c = stage_cost(g, &[id], &devs, &cluster.network);
            // Halo-only sync: replace the full gather/scatter comm with
            // the overlap traffic (see sim::sync for the same model).
            c.t_comm_stage *= halo_fraction(g, id);
            let total = c.t_comp_stage + c.t_comm_stage;
            if total < best_cost {
                best_cost = total;
                best_m = m;
            }
        }
        groups.push(SyncGroup {
            layers: vec![id],
            devices: order[..best_m].to_vec(),
            halo_sync: true,
        });
    }
    SyncSchedule { name: "CE".into(), groups }
}

/// Fraction of a layer's feature traffic that halo-only sync moves:
/// (kernel overlap rows) / (full tile rows). Connectors and 1x1 convs
/// sync nothing.
pub fn halo_fraction(g: &ModelGraph, id: usize) -> f64 {
    let l = g.layer(id);
    if !l.op.is_spatial() {
        return 0.0;
    }
    let halo = (l.kernel.0.saturating_sub(l.stride.0)) as f64;
    let h = g.shape(id).height() as f64;
    (halo / h).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modelzoo;

    #[test]
    fn coedge_uses_fewer_devices_on_small_features() {
        let g = modelzoo::vgg16();
        let c = Cluster::paper_heterogeneous();
        let s = coedge(&g, &c);
        // CoEdge's defining behaviour: the working set is *dynamic* —
        // wide mid-network features use more devices than the tiny 7x7
        // tail (which should collapse toward one fast device).
        let spatial: Vec<(&SyncGroup, usize)> = s
            .groups
            .iter()
            .filter(|gr| g.layer(gr.layers[0]).op.is_spatial())
            .map(|gr| (gr, g.shape(gr.layers[0]).height()))
            .collect();
        let widest = spatial.iter().max_by_key(|(_, h)| *h).unwrap();
        let narrowest = spatial.iter().min_by_key(|(_, h)| *h).unwrap();
        assert!(
            widest.0.devices.len() >= narrowest.0.devices.len(),
            "CE: {}-row layer uses {} devices but {}-row layer uses {}",
            widest.1,
            widest.0.devices.len(),
            narrowest.1,
            narrowest.0.devices.len()
        );
        let counts: std::collections::HashSet<usize> =
            s.groups.iter().map(|gr| gr.devices.len()).collect();
        assert!(counts.len() > 1, "device count must vary across layers");
        assert!(s.groups.iter().all(|gr| gr.halo_sync));
    }

    #[test]
    fn coedge_prefers_fast_devices() {
        let g = modelzoo::vgg16();
        let c = Cluster::paper_heterogeneous(); // 0,1 are TX2s
        let s = coedge(&g, &c);
        for gr in &s.groups {
            assert!(gr.devices.contains(&0), "fastest device always works");
        }
    }
}
