//! BFS — exhaustive search over pipeline configurations (§6.5's
//! optimality reference). Enumerates every split of the piece chain into
//! contiguous stages × every assignment of the (distinct) devices to the
//! stages, costing each with the same Eq. 7–12 model PICO uses. The
//! space is exponential — Tables 6–7 measure exactly that blowup — so a
//! wall-clock budget can cut the run (reported via `completed`).

use std::time::{Duration, Instant};

use crate::cluster::Cluster;
use crate::cost::pipeline_cost;
use crate::graph::{LayerId, ModelGraph};
use crate::partition::PieceChain;
use crate::pipeline::{PipelinePlan, Stage};

#[derive(Debug, Clone)]
pub struct BfsResult {
    pub plan: Option<PipelinePlan>,
    pub period: f64,
    pub latency: f64,
    /// Configurations fully costed.
    pub explored: u64,
    pub elapsed: Duration,
    /// False when the budget expired before the space was exhausted.
    pub completed: bool,
}

struct Search<'a> {
    g: &'a ModelGraph,
    pieces: &'a PieceChain,
    cluster: &'a Cluster,
    t_lim: f64,
    deadline: Option<Instant>,
    best: f64,
    best_cfg: Option<Vec<(usize, usize, Vec<usize>)>>,
    best_latency: f64,
    explored: u64,
    timed_out: bool,
}

impl<'a> Search<'a> {
    fn segment(&self, i: usize, j: usize) -> Vec<LayerId> {
        let mut ids: Vec<LayerId> = self.pieces[i..=j].iter().flatten().copied().collect();
        ids.sort_unstable();
        ids
    }

    /// Recurse over stage boundaries, then device assignments.
    fn stages(&mut self, from: usize, acc: &mut Vec<(usize, usize)>) {
        if self.timed_out() {
            return;
        }
        let l = self.pieces.len();
        if from == l {
            let bounds = acc.clone();
            let mut remaining: Vec<usize> = (0..self.cluster.len()).collect();
            let mut assign: Vec<Vec<usize>> = Vec::new();
            self.devices(&bounds, 0, &mut remaining, &mut assign);
            return;
        }
        for j in from..l {
            acc.push((from, j));
            self.stages(j + 1, acc);
            acc.pop();
            if self.timed_out() {
                return;
            }
        }
    }

    /// Assign every remaining device to stages `si..`: each stage takes
    /// any non-empty subset (stages are *ordered*, so every subset of the
    /// remaining pool is a distinct configuration — no symmetry to
    /// break; device order inside a stage is canonicalised at evaluate).
    fn devices(
        &mut self,
        bounds: &[(usize, usize)],
        si: usize,
        remaining: &mut Vec<usize>,
        assign: &mut Vec<Vec<usize>>,
    ) {
        if self.timed_out() {
            return;
        }
        if si == bounds.len() {
            if remaining.is_empty() {
                self.evaluate(bounds, assign);
            }
            return;
        }
        let stages_left = bounds.len() - si;
        if remaining.len() < stages_left {
            return;
        }
        let max_take = remaining.len() - (stages_left - 1);
        let pool = remaining.clone();
        let mut picked = vec![false; pool.len()];
        for size in 1..=max_take {
            self.choose(bounds, si, &pool, &mut picked, 0, size, assign);
            if self.timed_out() {
                return;
            }
        }
    }

    /// Pick `need` more devices from `pool[from..]` for stage `si`.
    #[allow(clippy::too_many_arguments)]
    fn choose(
        &mut self,
        bounds: &[(usize, usize)],
        si: usize,
        pool: &[usize],
        picked: &mut Vec<bool>,
        from: usize,
        need: usize,
        assign: &mut Vec<Vec<usize>>,
    ) {
        if self.timed_out() {
            return;
        }
        if need == 0 {
            let stage_devs: Vec<usize> =
                pool.iter().enumerate().filter(|(k, _)| picked[*k]).map(|(_, &d)| d).collect();
            let mut next_remaining: Vec<usize> =
                pool.iter().enumerate().filter(|(k, _)| !picked[*k]).map(|(_, &d)| d).collect();
            assign.push(stage_devs);
            self.devices(bounds, si + 1, &mut next_remaining, assign);
            assign.pop();
            return;
        }
        if from + need > pool.len() {
            return;
        }
        for k in from..pool.len() {
            picked[k] = true;
            self.choose(bounds, si, pool, picked, k + 1, need - 1, assign);
            picked[k] = false;
            if self.timed_out() {
                return;
            }
        }
    }

    fn evaluate(&mut self, bounds: &[(usize, usize)], assign: &[Vec<usize>]) {
        self.explored += 1;
        let stages: Vec<(Vec<LayerId>, Vec<usize>)> = bounds
            .iter()
            .zip(assign)
            .map(|(&(i, j), devs)| {
                // Fastest device leads the stage (its tile is excluded
                // from the distribute/gather traffic — always optimal),
                // matching Algorithm 3's ordering so the search space
                // strictly contains PICO's plans.
                let mut devs = devs.clone();
                devs.sort_by(|&a, &b| {
                    self.cluster.devices[b]
                        .flops
                        .partial_cmp(&self.cluster.devices[a].flops)
                        .unwrap()
                });
                (self.segment(i, j), devs)
            })
            .collect();
        let pc = pipeline_cost(self.g, self.cluster, &stages);
        if pc.latency <= self.t_lim && pc.period < self.best {
            self.best = pc.period;
            self.best_latency = pc.latency;
            self.best_cfg = Some(
                bounds
                    .iter()
                    .zip(assign)
                    .map(|(&(i, j), d)| (i, j, d.clone()))
                    .collect(),
            );
        }
    }

    fn timed_out(&mut self) -> bool {
        if self.timed_out {
            return true;
        }
        if let Some(dl) = self.deadline {
            // Check the clock every 256 evaluations to stay cheap.
            if self.explored % 256 == 0 && Instant::now() > dl {
                self.timed_out = true;
            }
        }
        self.timed_out
    }
}

/// Exhaustively find the best pipeline for `pieces` on `cluster`.
pub fn bfs_optimal(
    g: &ModelGraph,
    pieces: &PieceChain,
    cluster: &Cluster,
    t_lim: f64,
    budget: Option<Duration>,
) -> BfsResult {
    let start = Instant::now();
    let mut s = Search {
        g,
        pieces,
        cluster,
        t_lim,
        deadline: budget.map(|b| start + b),
        best: f64::INFINITY,
        best_cfg: None,
        best_latency: f64::INFINITY,
        explored: 0,
        timed_out: false,
    };
    let mut acc = Vec::new();
    s.stages(0, &mut acc);
    let best_cfg = s.best_cfg.take();
    let plan = best_cfg.map(|cfg| {
        PipelinePlan::pipelined(
            cfg.into_iter()
                .map(|(i, j, devices)| Stage::new((i, j), s.segment(i, j), devices))
                .collect(),
        )
    });
    BfsResult {
        plan,
        period: s.best,
        latency: s.best_latency,
        explored: s.explored,
        elapsed: start.elapsed(),
        completed: !s.timed_out,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modelzoo;
    use crate::partition;
    use crate::pipeline;

    #[test]
    fn bfs_matches_dp_on_homogeneous_chain() {
        // Theorem 4: Algorithm 2 is optimal for homogeneous devices on a
        // chain — BFS must agree with it exactly.
        let g = modelzoo::synthetic_chain(6);
        let pieces = partition::partition(&g, 5, None).unwrap().pieces;
        let c = Cluster::homogeneous_rpi(3, 1.0);
        let dp = pipeline::dp_pipeline(&g, &pieces, &c, f64::INFINITY).unwrap();
        let bfs = bfs_optimal(&g, &pieces, &c, f64::INFINITY, None);
        assert!(bfs.completed);
        assert!(
            (dp.period - bfs.period).abs() < 1e-9 * dp.period.max(1e-30),
            "DP {} vs BFS {}",
            dp.period,
            bfs.period
        );
    }

    #[test]
    fn bfs_never_worse_than_pico_heterogeneous() {
        let g = modelzoo::synthetic_chain(5);
        let pieces = partition::partition(&g, 5, None).unwrap().pieces;
        let mut c = Cluster::homogeneous_rpi(3, 1.0);
        c.devices[1].flops *= 0.6;
        c.devices[2].flops *= 1.5;
        let plan = pipeline::plan(&g, &pieces, &c, f64::INFINITY).unwrap();
        let pico_period = plan.cost(&g, &c).period;
        let bfs = bfs_optimal(&g, &pieces, &c, f64::INFINITY, None);
        assert!(bfs.completed);
        assert!(
            bfs.period <= pico_period + 1e-12,
            "BFS {} must lower-bound PICO {}",
            bfs.period,
            pico_period
        );
    }

    #[test]
    fn budget_cuts_search() {
        let g = modelzoo::synthetic_chain(12);
        let pieces = partition::partition(&g, 5, None).unwrap().pieces;
        let c = Cluster::homogeneous_rpi(8, 1.0);
        let bfs = bfs_optimal(&g, &pieces, &c, f64::INFINITY, Some(Duration::from_millis(30)));
        assert!(!bfs.completed, "12 pieces x 8 devices must exceed 30ms");
        assert!(bfs.explored > 0);
    }

    #[test]
    fn explored_count_grows_with_devices() {
        let g = modelzoo::synthetic_chain(4);
        let pieces = partition::partition(&g, 5, None).unwrap().pieces;
        let mut counts = Vec::new();
        for d in [2usize, 3, 4] {
            let c = Cluster::homogeneous_rpi(d, 1.0);
            let r = bfs_optimal(&g, &pieces, &c, f64::INFINITY, None);
            counts.push(r.explored);
        }
        assert!(counts[0] < counts[1] && counts[1] < counts[2], "{counts:?}");
    }
}
