//! LW — layer-wise parallelisation (MoDNN, [4] in the paper): every
//! layer's output feature is split over all devices; after each layer the
//! leader gathers and re-distributes. Maximum parallelism, maximum
//! communication.

use super::{SyncGroup, SyncSchedule};
use crate::cluster::Cluster;
use crate::graph::{ModelGraph, Op};

pub fn layer_wise(g: &ModelGraph, cluster: &Cluster) -> SyncSchedule {
    let all: Vec<usize> = (0..cluster.len()).collect();
    let groups = (0..g.n_layers())
        .filter(|&id| g.layer(id).op != Op::Input)
        .map(|id| SyncGroup { layers: vec![id], devices: all.clone(), halo_sync: false })
        .collect();
    SyncSchedule { name: "LW".into(), groups }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::modelzoo;

    #[test]
    fn one_group_per_layer() {
        let g = modelzoo::synthetic_chain(8);
        let c = Cluster::homogeneous_rpi(4, 1.0);
        let s = layer_wise(&g, &c);
        assert_eq!(s.groups.len(), g.n_layers() - 1);
        assert!(s.groups.iter().all(|gr| gr.devices.len() == 4 && !gr.halo_sync));
    }
}
