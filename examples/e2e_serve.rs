//! End-to-end validation: serve real inference requests through the full
//! three-layer stack and verify every byte.
//!
//! Pipeline: Pallas conv kernels (L1) → jax TinyVGG (L2) → AOT HLO-text
//! artifacts → rust PJRT runtime → threaded PICO coordinator (L3) with a
//! simulated 4-device cluster, all driven through the `Deployment`
//! facade: `DeploymentPlan::from_artifacts` wraps the AOT-exported plan,
//! `.serve(Backend::Pjrt, ...)` executes it. Every response is checked
//! bit-close against the single-executable PJRT whole-model run.
//!
//! Requires `make artifacts`. The run is recorded in EXPERIMENTS.md §E2E.
//!
//! ```bash
//! cargo run --release --example e2e_serve
//! ```

use std::path::PathBuf;
use std::sync::Arc;

use pico::coordinator::Request;
use pico::deploy::{Backend, DeploymentPlan, ServeConfig};
use pico::runtime::{Engine, PipelineArtifacts, Tensor};
use pico::util::{fmt_secs, Rng, Table};

fn main() -> anyhow::Result<()> {
    let dir = PathBuf::from("artifacts");
    anyhow::ensure!(dir.join("manifest.json").exists(), "run `make artifacts` first");

    let mut t = Table::new(&[
        "model", "stages", "devices", "requests", "max|Δ| vs full-model", "virt thpt /s",
        "virt period", "wall s",
    ]);
    for model in ["tinyvgg", "tinyresnet", "tinyinception"] {
        let row = serve_one(&dir, model)?;
        t.row(&row);
    }
    t.print();

    // Throughput comparison vs baselines for the tinyvgg deployment
    // (cost-model apples-to-apples): same model, same simulated
    // cluster, schemes swapped through the registry.
    let aot = DeploymentPlan::from_artifacts(&dir, "tinyvgg")?;
    println!("\nscheme comparison on tinyvgg, {} simulated rpi devices:", aot.cluster.len());
    let lw = DeploymentPlan::builder()
        .model("tinyvgg")
        .artifacts_dir(&dir)
        .cluster(aot.cluster.clone())
        .scheme("lw")
        .build()?
        .simulate(200)?;
    let ofl = DeploymentPlan::builder()
        .model("tinyvgg")
        .artifacts_dir(&dir)
        .cluster(aot.cluster.clone())
        .scheme("ofl")
        .build()?
        .simulate(200)?;
    let pico_r = aot.simulate(200)?;
    let mut ct = Table::new(&["scheme", "throughput /s", "vs LW"]);
    for r in [&lw, &ofl, &pico_r] {
        ct.row(&[
            r.scheme.clone(),
            format!("{:.2}", r.throughput),
            format!("{:.2}x", r.throughput / lw.throughput),
        ]);
    }
    ct.print();
    Ok(())
}

fn serve_one(dir: &PathBuf, model: &str) -> anyhow::Result<Vec<String>> {
    let d = DeploymentPlan::from_artifacts(dir, model)?;

    // Real image-like inputs (deterministic).
    let (c, h, w) = d.graph.input_shape;
    let mut rng = Rng::new(2024);
    let n_req = 32usize;
    let requests: Vec<Request> = (0..n_req as u64)
        .map(|id| Request {
            id,
            input: Tensor::new(
                vec![c, h, w],
                (0..c * h * w).map(|_| rng.normal() as f32).collect(),
            ),
            t_submit: 0.0,
        })
        .collect();

    // Ground truth: the whole-model AOT executable, one shot per request.
    let engine = Arc::new(Engine::cpu()?);
    let artifacts = Arc::new(PipelineArtifacts::load(dir, model)?);
    let full = artifacts.full_model(&engine)?;
    let expect: Vec<Tensor> =
        requests.iter().map(|r| full.run(&r.input)).collect::<Result<_, _>>()?;

    // Serve through the deployed pipeline.
    let cfg = ServeConfig { requests: Some(requests), ..ServeConfig::default() };
    let report = d.serve(&Backend::Pjrt { dir: dir.clone() }, &cfg)?;
    anyhow::ensure!(report.responses.len() == n_req, "lost responses");
    let mut max_diff = 0.0f32;
    for (resp, want) in report.responses.iter().zip(&expect) {
        max_diff = max_diff.max(resp.output.max_abs_diff(want));
    }
    anyhow::ensure!(max_diff < 1e-3, "{model}: pipeline diverged from full model: {max_diff}");

    Ok(vec![
        model.to_string(),
        format!("{}", d.replicas[0].stages.len()),
        format!("{}", d.cluster.len()),
        format!("{n_req}"),
        format!("{max_diff:.2e}"),
        format!("{:.2}", report.throughput),
        fmt_secs(report.period),
        format!("{:.2}", report.wall_secs),
    ])
}
