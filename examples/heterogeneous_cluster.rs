//! Heterogeneous-cluster walkthrough: the paper's §6.4 testbed
//! (2× Jetson TX2 NX + 6× Raspberry-Pi at mixed frequencies) running
//! VGG16 and YOLOv2 under every parallelisation scheme, reporting the
//! Table-5 metrics (utilisation, redundancy, memory) and Fig.-16 energy.
//!
//! ```bash
//! cargo run --release --example heterogeneous_cluster
//! ```

use pico::cluster::Cluster;
use pico::util::{fmt_secs, Table};
use pico::{baselines, modelzoo, partition, pipeline, sim};

fn main() -> anyhow::Result<()> {
    let cluster = Cluster::paper_heterogeneous();
    println!(
        "cluster: {}",
        cluster.devices.iter().map(|d| d.name.clone()).collect::<Vec<_>>().join(", ")
    );
    for model in ["vgg16", "yolov2"] {
        let g = modelzoo::by_name(model)?;
        println!("\n=== {} ===", g.name);
        let pieces = partition::partition(&g, 5, None)?.pieces;
        let n = 50;

        let ce = sim::simulate_sync(&g, &cluster, &baselines::coedge(&g, &cluster), n);
        let efl = sim::simulate_sync(&g, &cluster, &baselines::early_fused(&g, &cluster, 2), n);
        let ofl =
            sim::simulate_sync(&g, &cluster, &baselines::optimal_fused(&g, &pieces, &cluster), n);
        let plan = pipeline::plan(&g, &pieces, &cluster, f64::INFINITY)?;
        let pico_r = sim::simulate_pipeline(&g, &cluster, &plan, n);

        let mut t = Table::new(&[
            "scheme", "thpt /s", "latency", "avg util %", "avg redu %", "avg mem MB",
            "energy/task J",
        ]);
        for r in [&ce, &efl, &ofl, &pico_r] {
            t.row(&[
                r.scheme.clone(),
                format!("{:.3}", r.throughput),
                fmt_secs(r.latency),
                format!("{:.1}", r.avg_utilization() * 100.0),
                format!("{:.2}", r.avg_redundancy() * 100.0),
                format!("{:.1}", r.avg_mem() / 1e6),
                format!("{:.1}", r.energy_per_task()),
            ]);
        }
        t.print();

        // Per-device drill-down for PICO (Table 5's per-device columns).
        let mut pd = Table::new(&["device", "util %", "redu %", "mem MB"]);
        for d in &pico_r.per_device {
            pd.row(&[
                cluster.devices[d.device].name.clone(),
                format!("{:.1}", d.utilization * 100.0),
                format!("{:.2}", d.redundancy * 100.0),
                format!("{:.1}", (d.mem_model + d.mem_feature) as f64 / 1e6),
            ]);
        }
        println!("PICO per-device:");
        pd.print();
    }
    Ok(())
}
