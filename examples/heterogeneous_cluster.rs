//! Heterogeneous-cluster walkthrough: the paper's §6.4 testbed
//! (2× Jetson TX2 NX + 6× Raspberry-Pi at mixed frequencies) running
//! VGG16 and YOLOv2 under every registered parallelisation scheme,
//! reporting the Table-5 metrics (utilisation, redundancy, memory) and
//! Fig.-16 energy — all through the `Deployment` facade's scheme
//! registry.
//!
//! ```bash
//! cargo run --release --example heterogeneous_cluster
//! ```

use pico::cluster::Cluster;
use pico::deploy::DeploymentPlan;
use pico::util::{fmt_secs, Table};

fn main() -> Result<(), pico::PicoError> {
    let cluster = Cluster::paper_heterogeneous();
    println!(
        "cluster: {}",
        cluster.devices.iter().map(|d| d.name.clone()).collect::<Vec<_>>().join(", ")
    );
    for model in ["vgg16", "yolov2"] {
        println!("\n=== {model} ===");
        let n = 50;

        let mut t = Table::new(&[
            "scheme", "thpt /s", "latency", "avg util %", "avg redu %", "avg mem MB",
            "energy/task J",
        ]);
        let mut pico_report = None;
        for scheme in ["ce", "efl", "ofl", "pico"] {
            let d = DeploymentPlan::builder()
                .model(model)
                .cluster(cluster.clone())
                .scheme(scheme)
                .build()?;
            let r = d.simulate(n)?;
            t.row(&[
                r.scheme.clone(),
                format!("{:.3}", r.throughput),
                fmt_secs(r.latency),
                format!("{:.1}", r.avg_utilization() * 100.0),
                format!("{:.2}", r.avg_redundancy() * 100.0),
                format!("{:.1}", r.avg_mem() / 1e6),
                format!("{:.1}", r.energy_per_task()),
            ]);
            if scheme == "pico" {
                pico_report = Some(r);
            }
        }
        t.print();

        // Per-device drill-down for PICO (Table 5's per-device columns).
        let pico_r = pico_report.expect("pico scheme ran");
        let mut pd = Table::new(&["device", "util %", "redu %", "mem MB"]);
        for d in &pico_r.per_device {
            pd.row(&[
                cluster.devices[d.device].name.clone(),
                format!("{:.1}", d.utilization * 100.0),
                format!("{:.2}", d.redundancy * 100.0),
                format!("{:.1}", (d.mem_model + d.mem_feature) as f64 / 1e6),
            ]);
        }
        println!("PICO per-device:");
        pd.print();
    }
    Ok(())
}
