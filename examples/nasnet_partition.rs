//! NASNet-A-Large partition (the paper's §6.2.3 stress case): direct
//! Algorithm 1 is infeasible on a w=8 graph CNN — the divide-and-conquer
//! wrapper makes it tractable, reproducing Table 4's last two rows.
//!
//! ```bash
//! cargo run --release --example nasnet_partition
//! ```

use std::time::Duration;

use pico::cluster::Cluster;
use pico::deploy::DeploymentPlan;
use pico::graph::width;
use pico::util::{fmt_secs, Table};
use pico::{modelzoo, partition};

fn main() -> anyhow::Result<()> {
    let g = modelzoo::nasnet_large();
    let n = g.n_conv_pool();
    let w = width(&g);
    let d = 5usize;
    let bound = (w * d) as f64 * ((n * d) as f64 / w as f64).powi(w as i32);
    println!(
        "NASNet-A-Large: n={n} conv/pool vertices, width w={w}, bound wd(nd/w)^w = {bound:.1e}"
    );

    // Direct run with a short budget: expected to blow through it (the
    // paper reports >5h).
    let budget = Duration::from_secs(10);
    match partition::partition(&g, d, Some(budget)) {
        Ok(r) => println!("direct: unexpectedly finished with {} pieces", r.pieces.len()),
        Err(_) => println!(
            "direct: exceeded a {}s budget, as the paper's >5h row predicts",
            budget.as_secs()
        ),
    }

    // Divide-and-conquer (the paper's NASNetL-P row used 8 slices and
    // took 1.9h; slice size is the knob — 16/24/32 slices trade a little
    // boundary redundancy for orders of magnitude of time).
    let mut t = Table::new(&["parts", "pieces", "max redundancy FLOPs", "states", "time"]);
    for parts in [16usize, 24, 32] {
        let r = partition::partition_divide_conquer(&g, d, parts, Some(Duration::from_secs(300)))?;
        t.row(&[
            format!("{parts}"),
            format!("{}", r.pieces.len()),
            format!("{:.3e}", r.max_redundancy),
            format!("{}", r.states),
            fmt_secs(r.elapsed.as_secs_f64()),
        ]);
    }
    t.print();
    println!("(Algorithm 1 runs once per CNN regardless of cluster; the cost is offline.)");

    // The same divide-and-conquer knob through the Deployment facade: a
    // NASNet slice planned, explained and simulated end to end.
    let slice = modelzoo::nasnet_slice(1);
    let d = DeploymentPlan::builder()
        .graph(slice)
        .cluster(Cluster::paper_heterogeneous())
        .dc_parts(6)
        .partition_budget(Duration::from_secs(300))
        .build()?;
    print!("\n{}", d.explain());
    Ok(())
}
