//! Multi-replica serving walkthrough: one `Deployment` per replica
//! count over a heterogeneous cluster, driving a bursty request stream
//! through the event-driven coordinator — with bounded admission,
//! micro-batching and least-loaded dispatch — while verifying every
//! response against the whole-model reference.
//!
//! ```bash
//! cargo run --release --example replicated_serve
//! ```

use pico::cluster::{Cluster, Device, Network};
use pico::coordinator::{AdmissionPolicy, Request, ServeOptions};
use pico::deploy::{Backend, DeploymentPlan, Replicas, ServeConfig};
use pico::modelzoo;
use pico::runtime::executor::{model_weights, run_full_native};
use pico::runtime::Tensor;
use pico::util::{fmt_secs, Rng, Table};

fn main() -> anyhow::Result<()> {
    // A 6-device heterogeneous cluster: 2x Jetson TX2 NX + 4x RPi.
    let mut devices = vec![Device::tx2(0, 2.2), Device::tx2(1, 2.2)];
    for (i, ghz) in [1.5, 1.5, 1.2, 1.2].iter().enumerate() {
        devices.push(Device::rpi(2 + i, *ghz));
    }
    let cluster = Cluster::new(devices, Network::wifi_50mbps());
    println!(
        "cluster: {}",
        cluster.devices.iter().map(|d| d.name.clone()).collect::<Vec<_>>().join(", ")
    );

    // A DAG model with skip connections, small enough for real numerics.
    let g = modelzoo::synthetic_graph(3, 12);
    let weights_seed = 7u64;
    let weights = model_weights(&g, weights_seed);

    // A bursty arrival stream: Poisson-ish gaps around half the period.
    let mut rng = Rng::new(2026);
    let (c, h, w) = g.input_shape;
    let n_req = 48usize;
    let mut t = 0.0;
    let requests: Vec<Request> = (0..n_req as u64)
        .map(|id| {
            t += rng.f64() * 0.02;
            Request {
                id,
                input: Tensor::new(
                    vec![c, h, w],
                    (0..c * h * w).map(|_| rng.normal() as f32).collect(),
                ),
                t_submit: t,
            }
        })
        .collect();
    let expect: Vec<Tensor> = requests
        .iter()
        .map(|r| run_full_native(&g, &weights, &r.input))
        .collect::<Result<_, _>>()?;

    // Serve the same stream under three deployments.
    let opts = ServeOptions {
        queue_capacity: Some(16),
        max_batch: 4,
        admission: AdmissionPolicy::Block,
    };
    let mut table = Table::new(&[
        "deployment", "replicas", "throughput /s", "period", "p50 lat", "p95 lat", "rejected",
    ]);
    for replicas in [1usize, 2, 3] {
        let d = DeploymentPlan::builder()
            .graph(g.clone())
            .cluster(cluster.clone())
            .replicas(Replicas::Fixed(replicas))
            .build()?;
        let cfg = ServeConfig {
            requests: Some(requests.clone()),
            engine: opts.clone(),
            ..ServeConfig::default()
        };
        let report = d.serve(&Backend::Native { seed: weights_seed }, &cfg)?;
        anyhow::ensure!(report.responses.len() == n_req, "lost responses");
        for (resp, want) in report.responses.iter().zip(&expect) {
            let diff = resp.output.max_abs_diff(want);
            anyhow::ensure!(diff < 1e-3, "response {} diverged: {diff}", resp.id);
        }
        table.row(&[
            format!("{replicas} replica(s), Q=16, B=4"),
            format!("{replicas}"),
            format!("{:.2}", report.throughput),
            fmt_secs(report.period),
            fmt_secs(report.p50_latency),
            fmt_secs(report.p95_latency),
            format!("{}", report.rejected.len()),
        ]);
    }
    table.print();

    // Load shedding under a tight queue: overload is rejected, not
    // queued.
    let d = DeploymentPlan::builder()
        .graph(g.clone())
        .cluster(cluster.clone())
        .replicas(Replicas::Fixed(2))
        .build()?;
    let shed = d.serve(
        &Backend::Native { seed: weights_seed },
        &ServeConfig {
            requests: Some(requests.clone()),
            engine: ServeOptions {
                queue_capacity: Some(2),
                max_batch: 1,
                admission: AdmissionPolicy::Shed,
            },
            ..ServeConfig::default()
        },
    )?;
    println!(
        "\nshedding at Q=2: served {} of {n_req}, rejected {} (p95 latency {} vs blocking above)",
        shed.responses.len(),
        shed.rejected.len(),
        fmt_secs(shed.p95_latency)
    );
    Ok(())
}
