//! Quickstart: partition a CNN, plan a pipeline, compare against running
//! the same model on one device.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pico::cluster::Cluster;
use pico::util::{fmt_secs, Table};
use pico::{modelzoo, partition, pipeline, sim};

fn main() -> anyhow::Result<()> {
    // 1. A model from the zoo (any DAG: chain, block or graph structure).
    let g = modelzoo::vgg16();
    println!("model: {} ({} layers, {:.1} GFLOPs)", g.name, g.n_layers(), pico::cost::total_flops(&g) / 1e9);

    // 2. Algorithm 1: orchestrate the DAG into a chain of pieces.
    let pieces = partition::partition(&g, 5, None)?;
    println!(
        "Algorithm 1: {} pieces, max piece redundancy {:.3e} FLOPs ({})",
        pieces.pieces.len(),
        pieces.max_redundancy,
        fmt_secs(pieces.elapsed.as_secs_f64())
    );

    // 3. A cluster: four Raspberry-Pi 4Bs at 1.0 GHz over 50 Mbps Wi-Fi.
    let cluster = Cluster::homogeneous_rpi(4, 1.0);

    // 4. Algorithms 2+3: build the inference pipeline.
    let plan = pipeline::plan(&g, &pieces.pieces, &cluster, f64::INFINITY)?;
    let cost = plan.cost(&g, &cluster);
    println!(
        "PICO plan: {} stages, period {} -> {:.2} inferences/s (latency {})",
        plan.stages.len(),
        fmt_secs(cost.period),
        1.0 / cost.period,
        fmt_secs(cost.latency)
    );

    // 5. Compare with one device doing everything.
    let single = Cluster::homogeneous_rpi(1, 1.0);
    let single_pieces = partition::partition(&g, 5, None)?.pieces;
    let single_plan = pipeline::plan(&g, &single_pieces, &single, f64::INFINITY)?;
    let solo = sim::simulate_pipeline(&g, &single, &single_plan, 100);
    let pico_sim = sim::simulate_pipeline(&g, &cluster, &plan, 100);

    let mut t = Table::new(&["setup", "throughput /s", "latency", "avg util %", "avg mem MB"]);
    for r in [&solo, &pico_sim] {
        t.row(&[
            if r.per_device.len() == 1 { "1x Rpi".into() } else { "PICO 4x Rpi".into() },
            format!("{:.3}", r.throughput),
            fmt_secs(r.latency),
            format!("{:.1}", r.avg_utilization() * 100.0),
            format!("{:.1}", r.avg_mem() / 1e6),
        ]);
    }
    t.print();
    println!(
        "speedup: {:.2}x with 4 devices",
        pico_sim.throughput / solo.throughput
    );
    Ok(())
}
