//! Quickstart: the whole PICO workflow through the `Deployment` facade —
//! build a plan, inspect it, simulate it, serve it.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pico::cluster::Cluster;
use pico::deploy::{Backend, DeploymentPlan, ServeConfig};

fn main() -> Result<(), pico::PicoError> {
    // Builder → versioned plan artifact: model + cluster in, pipeline out.
    let plan = DeploymentPlan::builder()
        .model("vgg16")
        .cluster(Cluster::homogeneous_rpi(4, 1.0))
        .scheme("pico")
        .build()?;
    print!("{}", plan.explain());

    // The same artifact simulates analytically ...
    let sim = plan.simulate(100)?;
    println!("simulated: {:.2} inferences/s at latency {:.2}s", sim.throughput, sim.latency);

    // ... and serves through the threaded coordinator (timing backend).
    let report = plan.serve(&Backend::Null, &ServeConfig::default())?;
    println!("served {} requests: {:.2}/s", report.responses.len(), report.throughput);
    Ok(())
}
