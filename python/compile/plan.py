"""Pipeline-plan tile geometry: the paper's Eq. (2)-(3) in global row
coordinates, shared contract between the AOT exporter and the rust runtime.

PICO splits feature maps across devices by *rows* (1-D spatial partition,
full width). For a stage S = (segment M, devices D, output splits F^k) each
device k must produce rows F^k of every sink layer of M; the rows of every
interior layer it must compute follow from the top-down propagation of
§3.2.1:

    in_start = out_start * s - p            (global, may be < 0)
    in_end   = (out_end - 1) * s - p + k    (global, may exceed H)

Out-of-range rows are zero padding (the consumer's own conv padding at the
feature border); in-range rows outside the device's slice are the *halo*
fetched from the stage input. A layer consumed by several in-stage layers
produces the union (Eq. 2 max) and each consumer slices its sub-window.

The rust side implements the identical arithmetic in
`rust/src/cost/feature.rs`; `python/tests/test_plan.py` and the rust
integration tests pin both to the same golden values.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .model import LayerSpec, ModelSpec, layer_forward

# A row interval [start, end) in a layer's *output* grid, global coords.
Interval = tuple[int, int]


def required_rows(l: LayerSpec, out_iv: Interval) -> Interval:
    """Input rows (global, unclipped) needed to produce output rows out_iv.

    Eq. (3) of the paper, generalised with padding so border tiles know how
    much of their requirement is zero padding rather than neighbour halo.
    """
    s, e = out_iv
    assert e > s, f"empty interval {out_iv}"
    if l.op in ("conv", "maxpool", "avgpool"):
        sh = l.stride[0]
        kh = l.kernel[0]
        ph = l.padding[0]
        return (s * sh - ph, (e - 1) * sh - ph + kh)
    if l.op in ("add", "concat", "input"):
        return (s, e)
    raise ValueError(f"required_rows undefined for op {l.op}")


@dataclasses.dataclass
class LayerTile:
    """What one device computes for one layer of its stage segment."""

    layer: str
    out_iv: Interval  # rows of this layer's output the device produces (clipped)
    in_rows: int  # height of the (clipped) input slab fed to the layer
    pad_top: int  # zero rows added above (border padding)
    pad_bottom: int  # zero rows added below


def stage_tile_geometry(
    spec: ModelSpec,
    stage_layers: list[str],
    sink_out: dict[str, Interval],
) -> dict[str, LayerTile]:
    """Propagate required output intervals through a stage segment.

    `stage_layers` is a contiguous segment of the model DAG (topo order
    preserved); `sink_out` assigns the device's output rows for each sink
    layer (a layer whose consumers are all outside the segment).
    Returns per-layer tiles, including tiles for the segment's *source
    feeds* (layers outside the segment whose output the segment reads) —
    those entries have op "feed" semantics: out_iv = rows the device must
    fetch from the previous stage.
    """
    shapes = spec.shapes()
    in_stage = set(stage_layers)
    # Required output interval per layer = union over in-stage consumers.
    need: dict[str, Interval] = dict(sink_out)
    for name in reversed(stage_layers):
        l = spec.layer(name)
        if l.op in ("flatten", "dense"):
            # Heads need the full feature; only valid on an unsplit tile.
            src = l.inputs[0]
            h = shapes[src][1] if len(shapes[src]) == 3 else 1
            full = (0, h)
            if name in need:
                pass  # dense/flatten sinks produce their whole output
            for src_name in l.inputs:
                prev = need.get(src_name)
                iv = full if len(shapes[src_name]) == 3 else (0, 1)
                need[src_name] = _union(prev, iv)
            continue
        out_iv = need.get(name)
        if out_iv is None:
            raise ValueError(f"layer {name} has no consumer requirement")
        h_out = shapes[name][1]
        out_iv = _clip(out_iv, h_out)
        need[name] = out_iv
        req = required_rows(l, out_iv)
        for src_name in l.inputs:
            h_src = shapes[src_name][1] if len(shapes[src_name]) == 3 else 1
            prev = need.get(src_name)
            need[src_name] = _union(prev, _clip(req, h_src))

    tiles: dict[str, LayerTile] = {}
    for name in stage_layers:
        l = spec.layer(name)
        out_iv = _clip(need[name], shapes[name][1] if len(shapes[name]) == 3 else 1)
        if l.op in ("conv", "maxpool", "avgpool"):
            req = required_rows(l, out_iv)
            h_in = shapes[l.inputs[0]][1]
            pad_top = max(0, -req[0])
            pad_bottom = max(0, req[1] - h_in)
            in_rows = min(req[1], h_in) - max(req[0], 0)
            tiles[name] = LayerTile(name, out_iv, in_rows, pad_top, pad_bottom)
        else:
            in_rows = 0
            if l.inputs:
                src = l.inputs[0]
                if len(shapes[src]) == 3:
                    in_rows = _clip(need[src], shapes[src][1])[1] - _clip(need[src], shapes[src][1])[0]
            tiles[name] = LayerTile(name, out_iv, in_rows, 0, 0)
    # Source feeds: rows to fetch from the previous stage.
    for name in stage_layers:
        for src_name in spec.layer(name).inputs:
            if src_name not in in_stage and src_name not in tiles:
                h_src = shapes[src_name][1] if len(shapes[src_name]) == 3 else 1
                iv = _clip(need[src_name], h_src)
                tiles[src_name] = LayerTile(src_name, iv, 0, 0, 0)
    return tiles


def _union(a: Interval | None, b: Interval) -> Interval:
    if a is None:
        return b
    return (min(a[0], b[0]), max(a[1], b[1]))


def _clip(iv: Interval, h: int) -> Interval:
    s, e = max(iv[0], 0), min(iv[1], h)
    assert e > s, f"interval {iv} empty after clipping to height {h}"
    return (s, e)


def run_stage_tile(
    spec: ModelSpec,
    params,
    stage_layers: list[str],
    tiles: dict[str, LayerTile],
    feeds: dict[str, jnp.ndarray],
    impl: str = "pallas",
) -> dict[str, jnp.ndarray]:
    """Execute one device's share of a stage.

    `feeds` maps each segment source-feed layer name to the tensor slab
    covering tiles[feed].out_iv rows of that layer's output. Returns the
    produced slab for every in-stage layer (keyed by name); callers read
    the sink entries. This is the python twin of the rust stage executor —
    used to generate golden vectors and to validate the AOT artifacts.
    """
    shapes = spec.shapes()
    avail: dict[str, tuple[jnp.ndarray, Interval]] = {
        name: (feeds[name], tiles[name].out_iv) for name in feeds
    }
    out: dict[str, jnp.ndarray] = {}
    for name in stage_layers:
        l = spec.layer(name)
        t = tiles[name]
        if l.op in ("conv", "maxpool", "avgpool"):
            req = required_rows(l, t.out_iv)
            src_t, src_iv = avail[l.inputs[0]]
            lo = max(req[0], 0)
            hi = min(req[1], shapes[l.inputs[0]][1])
            x = src_t[:, lo - src_iv[0] : hi - src_iv[0], :]
            pad = (t.pad_top, t.pad_bottom, l.padding[1], l.padding[1])
            y = layer_forward(l, params, [x], impl, pad_override=pad)
        elif l.op == "add":
            xs = []
            for src in l.inputs:
                src_t, src_iv = avail[src]
                xs.append(src_t[:, t.out_iv[0] - src_iv[0] : t.out_iv[1] - src_iv[0], :])
            y = layer_forward(l, params, xs, impl)
        elif l.op == "concat":
            xs = []
            for src in l.inputs:
                src_t, src_iv = avail[src]
                xs.append(src_t[:, t.out_iv[0] - src_iv[0] : t.out_iv[1] - src_iv[0], :])
            y = layer_forward(l, params, xs, impl)
        elif l.op in ("flatten", "dense"):
            src_t, src_iv = avail[l.inputs[0]]
            if l.op == "flatten":
                h = shapes[l.inputs[0]][1]
                assert src_iv == (0, h), "flatten requires the full feature"
            y = layer_forward(l, params, [src_t], impl)
        else:
            raise ValueError(f"unexpected op {l.op}")
        avail[name] = (y, t.out_iv)
        out[name] = y
    return out


def row_splits(h: int, parts: int) -> list[Interval]:
    """Equal row split of an output height (remainder spread from the top),
    identical to rust `runtime::tensor::row_splits`."""
    assert 1 <= parts <= h, f"cannot split {h} rows into {parts} parts"
    base, rem = divmod(h, parts)
    ivs = []
    s = 0
    for i in range(parts):
        e = s + base + (1 if i < rem else 0)
        ivs.append((s, e))
        s = e
    return ivs
