"""Pallas pooling kernels (max / average).

Same row-tile grid and halo-window scheme as conv2d.py: the grid walks
output row tiles, the (much smaller) input stays resident and each step
loads its overlapping window with `pl.dslice`. Pool layers are <1% of the
FLOPs (paper Fig. 2) but change the feature geometry, so the rust cost
model and these kernels must agree exactly on output shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_row_tile(h_out: int, target: int = 8) -> int:
    best = 1
    for th in range(1, min(h_out, target) + 1):
        if h_out % th == 0:
            best = th
    return best


def _pool_kernel(x_ref, o_ref, *, th, sh, sw, kh, kw, op):
    i = pl.program_id(0)
    c, _, w_out = o_ref.shape
    in_rows = th * sh + kh - sh
    x = x_ref[:, pl.dslice(i * th * sh, in_rows), :]
    if op == "max":
        acc = jnp.full((c, th, w_out), -jnp.inf, dtype=jnp.float32)
    else:
        acc = jnp.zeros((c, th, w_out), dtype=jnp.float32)
    for dh in range(kh):
        for dw in range(kw):
            patch = jax.lax.slice(
                x,
                (0, dh, dw),
                (c, dh + (th - 1) * sh + 1, dw + (w_out - 1) * sw + 1),
                (1, sh, sw),
            )
            acc = jnp.maximum(acc, patch) if op == "max" else acc + patch
    o_ref[...] = acc if op == "max" else acc / float(kh * kw)


def _pool(x, kernel, stride, padding, op, interpret):
    kh, kw = kernel
    sh, sw = stride if stride is not None else kernel
    ph, pw = padding
    if ph or pw:
        pad_value = -jnp.inf if op == "max" else 0.0
        x = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw)), constant_values=pad_value)
    c, h_in, w_in = x.shape
    h_out = (h_in - kh) // sh + 1
    w_out = (w_in - kw) // sw + 1
    assert h_out >= 1 and w_out >= 1, "pool window larger than padded input"
    th = _pick_row_tile(h_out)

    kern = functools.partial(_pool_kernel, th=th, sh=sh, sw=sw, kh=kh, kw=kw, op=op)
    return pl.pallas_call(
        kern,
        grid=(h_out // th,),
        in_specs=[pl.BlockSpec(x.shape, lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((c, th, w_out), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, h_out, w_out), x.dtype),
        interpret=interpret,
    )(x)


def maxpool2d(
    x: jnp.ndarray,
    kernel: tuple[int, int] = (2, 2),
    stride: tuple[int, int] | None = None,
    padding: tuple[int, int] = (0, 0),
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas max-pool matching `ref.maxpool2d`. x: (C, H, W)."""
    return _pool(x, kernel, stride, padding, "max", interpret)


def avgpool2d(
    x: jnp.ndarray,
    kernel: tuple[int, int] = (2, 2),
    stride: tuple[int, int] | None = None,
    padding: tuple[int, int] = (0, 0),
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas average-pool matching `ref.avgpool2d`. x: (C, H, W)."""
    return _pool(x, kernel, stride, padding, "avg", interpret)
