"""Pallas dense (fully-connected) kernel.

Classifier heads (VGG16's three fc layers, Tiny models' head) are a plain
matmul. The grid tiles output rows of the weight matrix; each step computes
one (TO,)-slice of the output as a (TO, F) x (F,) contraction — the
MXU-shaped primitive — then adds bias and activation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _pick_row_tile(n: int, target: int = 128) -> int:
    best = 1
    for t in range(1, min(n, target) + 1):
        if n % t == 0:
            best = t
    return best


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, activation):
    y = jnp.dot(w_ref[...], x_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = ref.apply_activation(y + b_ref[...], activation)


def dense(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray | None = None,
    activation: str = "linear",
    row_tile: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas dense layer matching `ref.dense`. x: (F,), w: (O, F)."""
    o, f = w.shape
    assert x.shape == (f,), f"shape mismatch: x {x.shape} vs w {w.shape}"
    if b is None:
        b = jnp.zeros((o,), dtype=x.dtype)
    to = row_tile if row_tile is not None else _pick_row_tile(o)
    assert o % to == 0, f"row tile {to} must divide O {o}"

    kern = functools.partial(_dense_kernel, activation=activation)
    return pl.pallas_call(
        kern,
        grid=(o // to,),
        in_specs=[
            pl.BlockSpec((f,), lambda i: (0,)),
            pl.BlockSpec((to, f), lambda i: (i, 0)),
            pl.BlockSpec((to,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((to,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((o,), x.dtype),
        interpret=interpret,
    )(x, w, b)
