"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth for the L1 kernels: every Pallas
kernel in this package must match its oracle to float32 tolerance on all
shapes the e2e models use (and on the hypothesis sweeps in python/tests).

Conventions (shared with kernels and with the rust runtime):
  * features are CHW float32, no batch dimension — the serving pipeline
    moves single frames (tiles) between devices, batching happens upstream;
  * conv weights are (C_out, C_in, KH, KW), bias (C_out,);
  * padding is explicit (ph, pw) zero padding, stride (sh, sw);
  * activations: "linear", "relu", "leaky" (YOLO-style slope 0.1).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def apply_activation(y: jnp.ndarray, activation: str) -> jnp.ndarray:
    """Apply one of the supported activation functions."""
    if activation == "linear":
        return y
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "leaky":
        return jnp.where(y > 0, y, 0.1 * y)
    raise ValueError(f"unknown activation {activation!r}")


def conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray | None = None,
    stride: tuple[int, int] = (1, 1),
    padding: tuple[int, int] = (0, 0),
    activation: str = "linear",
) -> jnp.ndarray:
    """2D convolution oracle.

    x: (C_in, H, W); w: (C_out, C_in, KH, KW); b: (C_out,) or None.
    Returns (C_out, H_out, W_out) with H_out = (H + 2ph - KH)//sh + 1.
    """
    sh, sw = stride
    ph, pw = padding
    y = jax.lax.conv_general_dilated(
        x[None],  # NCHW
        w,  # OIHW
        window_strides=(sh, sw),
        padding=((ph, ph), (pw, pw)),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    if b is not None:
        y = y + b[:, None, None]
    return apply_activation(y, activation)


def maxpool2d(
    x: jnp.ndarray,
    kernel: tuple[int, int] = (2, 2),
    stride: tuple[int, int] | None = None,
    padding: tuple[int, int] = (0, 0),
) -> jnp.ndarray:
    """Max-pooling oracle. x: (C, H, W)."""
    kh, kw = kernel
    sh, sw = stride if stride is not None else kernel
    ph, pw = padding
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, kh, kw),
        window_strides=(1, sh, sw),
        padding=((0, 0), (ph, ph), (pw, pw)),
    )


def avgpool2d(
    x: jnp.ndarray,
    kernel: tuple[int, int] = (2, 2),
    stride: tuple[int, int] | None = None,
    padding: tuple[int, int] = (0, 0),
) -> jnp.ndarray:
    """Average-pooling oracle (count_include_pad=True, matches rust runtime)."""
    kh, kw = kernel
    sh, sw = stride if stride is not None else kernel
    ph, pw = padding
    summed = jax.lax.reduce_window(
        x,
        0.0,
        jax.lax.add,
        window_dimensions=(1, kh, kw),
        window_strides=(1, sh, sw),
        padding=((0, 0), (ph, ph), (pw, pw)),
    )
    return summed / float(kh * kw)


def dense(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray | None = None,
    activation: str = "linear",
) -> jnp.ndarray:
    """Fully-connected oracle. x: (F,), w: (O, F), b: (O,)."""
    y = w @ x
    if b is not None:
        y = y + b
    return apply_activation(y, activation)


def add(xs: list[jnp.ndarray]) -> jnp.ndarray:
    """Elementwise sum connector (ResNet skip connections)."""
    out = xs[0]
    for x in xs[1:]:
        out = out + x
    return out


def concat(xs: list[jnp.ndarray]) -> jnp.ndarray:
    """Channel-dimension concat connector (Inception blocks)."""
    return jnp.concatenate(xs, axis=0)
