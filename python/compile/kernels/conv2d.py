"""Pallas conv2d kernel — the L1 compute hot-spot of a PICO device.

One pipeline-stage device executes its model segment over a spatial tile of
the feature map. The dominant cost (>99% of FLOPs for VGG16/YOLOv2, paper
Fig. 2) is the conv layer, implemented here as a Pallas kernel.

Tiling scheme
-------------
The grid walks row-tiles of the *output* feature map: grid step `i` produces
output rows [i*TH, (i+1)*TH). Because consecutive output tiles need
*overlapping* input rows (the halo: TH*sh + KH - sh input rows per tile,
shifted by TH*sh), the input cannot be expressed as a disjoint BlockSpec
partition; we therefore keep the input resident (memory_space ANY) and load
each tile's halo window with `pl.dslice` inside the kernel. On a real TPU
this becomes a manual HBM→VMEM DMA schedule (double-buffering the next halo
window while the MXU contracts the current one); under `interpret=True` the
same structure runs as numpy and is validated against `ref.conv2d`.

Within a tile the contraction is laid out MXU-friendly: a static (KH, KW)
unroll of `einsum('chw,oc->ohw')` — i.e. KH*KW dot products over C_in with
the spatial dims vectorised, which lowers to the same contraction shape an
im2col×weights matmul would feed the systolic array.

VMEM accounting (per grid step, f32):
  input window  C_in  * (TH*sh + KH - sh) * W_in
  weights       C_out * C_in * KH * KW
  output tile   C_out * TH * W_out
`vmem_bytes()` below computes this; the kernel picker keeps it under the
16 MiB VMEM budget documented in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _pick_row_tile(h_out: int, target: int = 8) -> int:
    """Largest divisor of h_out that is <= target (so the grid is exact)."""
    best = 1
    for th in range(1, min(h_out, target) + 1):
        if h_out % th == 0:
            best = th
    return best


def _conv_kernel(x_ref, w_ref, b_ref, o_ref, *, th, sh, sw, kh, kw, activation):
    """Grid step: produce output rows [i*th, (i+1)*th) for all channels."""
    i = pl.program_id(0)
    c_out, _, w_out = o_ref.shape
    c_in = x_ref.shape[0]
    # Halo window of input rows feeding this output tile.
    in_rows = th * sh + kh - sh
    x = x_ref[:, pl.dslice(i * th * sh, in_rows), :]
    acc = jnp.zeros((c_out, th, w_out), dtype=jnp.float32)
    # Static unroll over kernel taps; each tap is a C_in contraction with the
    # spatial dims vectorised (MXU-shaped under a real TPU lowering).
    for dh in range(kh):
        for dw in range(kw):
            # rows dh, dh+sh, ..., cols dw, dw+sw, ...
            patch = jax.lax.slice(
                x,
                (0, dh, dw),
                (c_in, dh + (th - 1) * sh + 1, dw + (w_out - 1) * sw + 1),
                (1, sh, sw),
            )
            acc = acc + jnp.einsum(
                "chw,oc->ohw", patch, w_ref[:, :, dh, dw],
                preferred_element_type=jnp.float32,
            )
    acc = acc + b_ref[...][:, None, None]
    o_ref[...] = ref.apply_activation(acc, activation)


def vmem_bytes(
    c_in: int,
    c_out: int,
    h_out: int,
    w_in: int,
    w_out: int,
    kernel: tuple[int, int],
    stride: tuple[int, int],
    row_tile: int | None = None,
) -> int:
    """Per-grid-step VMEM footprint estimate in bytes (f32)."""
    kh, kw = kernel
    sh, _ = stride
    th = row_tile if row_tile is not None else _pick_row_tile(h_out)
    in_rows = th * sh + kh - sh
    return 4 * (c_in * in_rows * w_in + c_out * c_in * kh * kw + c_out * th * w_out)


def conv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray | None = None,
    stride: tuple[int, int] = (1, 1),
    padding: tuple[int, int] = (0, 0),
    activation: str = "linear",
    row_tile: int | None = None,
    interpret: bool = True,
) -> jnp.ndarray:
    """Pallas conv2d matching `ref.conv2d` exactly.

    x: (C_in, H, W); w: (C_out, C_in, KH, KW); b: (C_out,) or None.
    `interpret=True` is mandatory for CPU-PJRT execution (real TPU lowering
    emits a Mosaic custom-call the CPU plugin cannot run).
    """
    c_out, c_in, kh, kw = w.shape
    assert x.shape[0] == c_in, f"C_in mismatch: {x.shape[0]} vs {c_in}"
    sh, sw = stride
    ph, pw = padding
    if ph or pw:
        x = jnp.pad(x, ((0, 0), (ph, ph), (pw, pw)))
    _, h_in, w_in = x.shape
    h_out = (h_in - kh) // sh + 1
    w_out = (w_in - kw) // sw + 1
    assert h_out >= 1 and w_out >= 1, "kernel larger than padded input"
    if b is None:
        b = jnp.zeros((c_out,), dtype=x.dtype)
    th = row_tile if row_tile is not None else _pick_row_tile(h_out)
    assert h_out % th == 0, f"row tile {th} must divide H_out {h_out}"

    kern = functools.partial(
        _conv_kernel, th=th, sh=sh, sw=sw, kh=kh, kw=kw, activation=activation
    )
    return pl.pallas_call(
        kern,
        grid=(h_out // th,),
        in_specs=[
            pl.BlockSpec(x.shape, lambda i: (0, 0, 0)),  # halo: resident input
            pl.BlockSpec(w.shape, lambda i: (0, 0, 0, 0)),
            pl.BlockSpec(b.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((c_out, th, w_out), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((c_out, h_out, w_out), x.dtype),
        interpret=interpret,
    )(x, w, b)
