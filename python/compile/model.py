"""L2: CNN models as layer-spec DAGs executed with the L1 Pallas kernels.

A model is a `ModelSpec`: an ordered list of `LayerSpec`s forming a DAG
(inputs reference earlier layer names). The same spec is exported as JSON
and loaded by the rust coordinator (`rust/src/graph/`), so python (numerics)
and rust (scheduling/runtime) agree layer-for-layer.

Three e2e models are defined here, small enough to AOT-lower per-tile on
CPU, each exercising one structure class from the paper's §2.3:
  * tiny_vgg       — chain structure (VGG16-style conv/pool body + fc head);
  * tiny_resnet    — block structure with Add skip connections (ResNet34);
  * tiny_inception — block structure with multi-branch Concat and the
                     unbalanced 1x7/7x1 kernels of InceptionV3's Fig. 6 case.

`forward()` runs a spec either with the Pallas kernels (impl="pallas", the
lowering used for AOT artifacts) or the pure-jnp oracles (impl="ref").
"""

from __future__ import annotations

import dataclasses
import json
from typing import Callable

import jax.numpy as jnp
import numpy as np

from .kernels import conv2d as kconv
from .kernels import matmul as kmatmul
from .kernels import pool as kpool
from .kernels import ref

OPS = ("input", "conv", "maxpool", "avgpool", "add", "concat", "flatten", "dense")


@dataclasses.dataclass
class LayerSpec:
    """One vertex of the CNN DAG (paper notation: layer l_i)."""

    name: str
    op: str
    inputs: list[str] = dataclasses.field(default_factory=list)
    out_channels: int = 0  # conv: C_out; dense: units
    kernel: tuple[int, int] = (1, 1)
    stride: tuple[int, int] = (1, 1)
    padding: tuple[int, int] = (0, 0)
    activation: str = "linear"

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "op": self.op,
            "inputs": list(self.inputs),
            "out_channels": self.out_channels,
            "kernel": list(self.kernel),
            "stride": list(self.stride),
            "padding": list(self.padding),
            "activation": self.activation,
        }


@dataclasses.dataclass
class ModelSpec:
    """A CNN model: DAG of layers, topologically ordered."""

    name: str
    input_shape: tuple[int, int, int]  # (C, H, W)
    layers: list[LayerSpec]

    def layer(self, name: str) -> LayerSpec:
        for l in self.layers:
            if l.name == name:
                return l
        raise KeyError(name)

    def consumers(self, name: str) -> list[LayerSpec]:
        return [l for l in self.layers if name in l.inputs]

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "input_shape": list(self.input_shape),
            "layers": [l.to_json() for l in self.layers],
        }

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)

    # ---- shape inference (must agree with rust/src/graph/shape.rs) ----

    def shapes(self) -> dict[str, tuple[int, ...]]:
        """Output shape of every layer."""
        out: dict[str, tuple[int, ...]] = {}
        for l in self.layers:
            if l.op == "input":
                out[l.name] = self.input_shape
                continue
            ins = [out[i] for i in l.inputs]
            if l.op == "conv":
                c, h, w = ins[0]
                kh, kw = l.kernel
                sh, sw = l.stride
                ph, pw = l.padding
                out[l.name] = (
                    l.out_channels,
                    (h + 2 * ph - kh) // sh + 1,
                    (w + 2 * pw - kw) // sw + 1,
                )
            elif l.op in ("maxpool", "avgpool"):
                c, h, w = ins[0]
                kh, kw = l.kernel
                sh, sw = l.stride
                ph, pw = l.padding
                out[l.name] = (c, (h + 2 * ph - kh) // sh + 1, (w + 2 * pw - kw) // sw + 1)
            elif l.op == "add":
                assert len(set(ins)) == 1, f"add inputs differ: {ins}"
                out[l.name] = ins[0]
            elif l.op == "concat":
                c = sum(s[0] for s in ins)
                assert len({s[1:] for s in ins}) == 1, f"concat spatial differ: {ins}"
                out[l.name] = (c, ins[0][1], ins[0][2])
            elif l.op == "flatten":
                n = 1
                for d in ins[0]:
                    n *= d
                out[l.name] = (n,)
            elif l.op == "dense":
                out[l.name] = (l.out_channels,)
            else:
                raise ValueError(f"unknown op {l.op}")
        return out


# ----------------------------------------------------------------- params


def init_params(spec: ModelSpec, seed: int = 0) -> dict[str, dict[str, np.ndarray]]:
    """He-style random weights, deterministic per (model, seed)."""
    rng = np.random.default_rng(seed)
    shapes = spec.shapes()
    params: dict[str, dict[str, np.ndarray]] = {}
    for l in spec.layers:
        if l.op == "conv":
            c_in = shapes[l.inputs[0]][0]
            kh, kw = l.kernel
            fan_in = c_in * kh * kw
            params[l.name] = {
                "w": (rng.standard_normal((l.out_channels, c_in, kh, kw)) * np.sqrt(2.0 / fan_in)).astype(np.float32),
                "b": (rng.standard_normal((l.out_channels,)) * 0.01).astype(np.float32),
            }
        elif l.op == "dense":
            f = shapes[l.inputs[0]][0]
            params[l.name] = {
                "w": (rng.standard_normal((l.out_channels, f)) * np.sqrt(2.0 / f)).astype(np.float32),
                "b": (rng.standard_normal((l.out_channels,)) * 0.01).astype(np.float32),
            }
    return params


# ---------------------------------------------------------------- forward


def layer_forward(
    l: LayerSpec,
    params: dict[str, dict[str, np.ndarray]],
    xs: list[jnp.ndarray],
    impl: str = "pallas",
    pad_override: tuple[int, int, int, int] | None = None,
) -> jnp.ndarray:
    """Execute one layer. `pad_override` = (top, bottom, left, right): used
    for tile execution where border tiles get asymmetric padding (interior
    halo rows come from the neighbouring tile instead of zero padding)."""
    use_pallas = impl == "pallas"
    if l.op == "input":
        return xs[0]
    if l.op == "conv":
        w = jnp.asarray(params[l.name]["w"])
        b = jnp.asarray(params[l.name]["b"])
        x = xs[0]
        if pad_override is not None:
            pt, pb, pleft, pright = pad_override
            x = jnp.pad(x, ((0, 0), (pt, pb), (pleft, pright)))
            pad = (0, 0)
        else:
            pad = l.padding
        if use_pallas:
            return kconv.conv2d(x, w, b, l.stride, pad, l.activation)
        return ref.conv2d(x, w, b, l.stride, pad, l.activation)
    if l.op in ("maxpool", "avgpool"):
        x = xs[0]
        if pad_override is not None:
            pt, pb, pleft, pright = pad_override
            cval = -jnp.inf if l.op == "maxpool" else 0.0
            x = jnp.pad(x, ((0, 0), (pt, pb), (pleft, pright)), constant_values=cval)
            pad = (0, 0)
        else:
            pad = l.padding
        fn_pallas = kpool.maxpool2d if l.op == "maxpool" else kpool.avgpool2d
        fn_ref = ref.maxpool2d if l.op == "maxpool" else ref.avgpool2d
        if use_pallas:
            return fn_pallas(x, l.kernel, l.stride, pad)
        return fn_ref(x, l.kernel, l.stride, pad)
    if l.op == "add":
        return ref.add(xs)
    if l.op == "concat":
        return ref.concat(xs)
    if l.op == "flatten":
        return xs[0].reshape(-1)
    if l.op == "dense":
        w = jnp.asarray(params[l.name]["w"])
        b = jnp.asarray(params[l.name]["b"])
        if use_pallas:
            return kmatmul.dense(xs[0], w, b, l.activation)
        return ref.dense(xs[0], w, b, l.activation)
    raise ValueError(f"unknown op {l.op}")


def forward(
    spec: ModelSpec,
    params: dict[str, dict[str, np.ndarray]],
    x: jnp.ndarray,
    impl: str = "pallas",
) -> jnp.ndarray:
    """Full-model forward pass; returns the last layer's output."""
    acts: dict[str, jnp.ndarray] = {}
    for l in spec.layers:
        if l.op == "input":
            acts[l.name] = x
        else:
            acts[l.name] = layer_forward(l, params, [acts[i] for i in l.inputs], impl)
    return acts[spec.layers[-1].name]


def forward_fn(
    spec: ModelSpec, params: dict[str, dict[str, np.ndarray]], impl: str = "pallas"
) -> Callable[[jnp.ndarray], tuple[jnp.ndarray, ...]]:
    """Closure (weights baked) suitable for jax.jit().lower() — AOT entry."""

    def fn(x):
        return (forward(spec, params, x, impl),)

    return fn


# ------------------------------------------------------------ e2e models


def tiny_vgg(input_hw: int = 32) -> ModelSpec:
    """Chain-structure e2e model (VGG16 body shrunk to 32x32)."""
    L = LayerSpec
    return ModelSpec(
        name="tinyvgg",
        input_shape=(3, input_hw, input_hw),
        layers=[
            L("input", "input"),
            L("conv1", "conv", ["input"], 16, (3, 3), (1, 1), (1, 1), "relu"),
            L("conv2", "conv", ["conv1"], 16, (3, 3), (1, 1), (1, 1), "relu"),
            L("pool1", "maxpool", ["conv2"], kernel=(2, 2), stride=(2, 2)),
            L("conv3", "conv", ["pool1"], 32, (3, 3), (1, 1), (1, 1), "relu"),
            L("conv4", "conv", ["conv3"], 32, (3, 3), (1, 1), (1, 1), "relu"),
            L("pool2", "maxpool", ["conv4"], kernel=(2, 2), stride=(2, 2)),
            L("conv5", "conv", ["pool2"], 64, (3, 3), (1, 1), (1, 1), "relu"),
            L("pool3", "maxpool", ["conv5"], kernel=(2, 2), stride=(2, 2)),
            L("flatten", "flatten", ["pool3"]),
            L("fc1", "dense", ["flatten"], 64, activation="relu"),
            L("fc2", "dense", ["fc1"], 10),
        ],
    )


def tiny_resnet(input_hw: int = 32) -> ModelSpec:
    """Block-structure e2e model with ResNet-style Add skip connections."""
    L = LayerSpec
    return ModelSpec(
        name="tinyresnet",
        input_shape=(3, input_hw, input_hw),
        layers=[
            L("input", "input"),
            L("stem", "conv", ["input"], 16, (3, 3), (1, 1), (1, 1), "relu"),
            # residual block 1 (identity skip)
            L("b1_conv1", "conv", ["stem"], 16, (3, 3), (1, 1), (1, 1), "relu"),
            L("b1_conv2", "conv", ["b1_conv1"], 16, (3, 3), (1, 1), (1, 1)),
            L("b1_add", "add", ["b1_conv2", "stem"]),
            # residual block 2 (strided, 1x1 projection skip)
            L("b2_conv1", "conv", ["b1_add"], 32, (3, 3), (2, 2), (1, 1), "relu"),
            L("b2_conv2", "conv", ["b2_conv1"], 32, (3, 3), (1, 1), (1, 1)),
            L("b2_proj", "conv", ["b1_add"], 32, (1, 1), (2, 2), (0, 0)),
            L("b2_add", "add", ["b2_conv2", "b2_proj"]),
            L("pool", "maxpool", ["b2_add"], kernel=(2, 2), stride=(2, 2)),
            L("flatten", "flatten", ["pool"]),
            L("fc", "dense", ["flatten"], 10),
        ],
    )


def tiny_inception(input_hw: int = 32) -> ModelSpec:
    """Block-structure e2e model with multi-branch Concat, including the
    unbalanced 1x7 / 7x1 kernel pair from the paper's Fig. 6."""
    L = LayerSpec
    return ModelSpec(
        name="tinyinception",
        input_shape=(3, input_hw, input_hw),
        layers=[
            L("input", "input"),
            L("stem", "conv", ["input"], 16, (3, 3), (2, 2), (1, 1), "relu"),
            # branch a: pointwise
            L("a_1x1", "conv", ["stem"], 8, (1, 1), (1, 1), (0, 0), "relu"),
            # branch b: 1x1 -> 3x3
            L("b_1x1", "conv", ["stem"], 8, (1, 1), (1, 1), (0, 0), "relu"),
            L("b_3x3", "conv", ["b_1x1"], 8, (3, 3), (1, 1), (1, 1), "relu"),
            # branch c: the Fig. 6 unbalanced pair 1x7 then 7x1
            L("c_1x7", "conv", ["stem"], 8, (1, 7), (1, 1), (0, 3), "relu"),
            L("c_7x1", "conv", ["c_1x7"], 8, (7, 1), (1, 1), (3, 0), "relu"),
            # branch d: pooled shortcut
            L("d_pool", "maxpool", ["stem"], kernel=(3, 3), stride=(1, 1), padding=(1, 1)),
            L("d_1x1", "conv", ["d_pool"], 8, (1, 1), (1, 1), (0, 0), "relu"),
            L("cat", "concat", ["a_1x1", "b_3x3", "c_7x1", "d_1x1"]),
            L("tail", "conv", ["cat"], 32, (3, 3), (2, 2), (1, 1), "relu"),
            L("pool", "maxpool", ["tail"], kernel=(2, 2), stride=(2, 2)),
            L("flatten", "flatten", ["pool"]),
            L("fc", "dense", ["flatten"], 10),
        ],
    )


E2E_MODELS: dict[str, Callable[[], ModelSpec]] = {
    "tinyvgg": tiny_vgg,
    "tinyresnet": tiny_resnet,
    "tinyinception": tiny_inception,
}
