"""AOT compile path: lower the e2e models to HLO text for the rust runtime.

Run once at build time (`make artifacts`); python never runs at request
time. For every artifact we:

    lowered = jax.jit(fn).lower(example_input)
    stablehlo = lowered.compiler_ir("stablehlo")
    comp = xla_client mlir->XlaComputation (return_tuple=True)
    write comp.as_hlo_text()

HLO *text* is the interchange format — the `xla` crate's xla_extension
0.5.1 rejects jax>=0.5 serialized HloModuleProtos (64-bit instruction ids);
the text parser reassigns ids (see /opt/xla-example/README.md).

Exported per model (weights baked in as HLO constants, seed-deterministic):
  artifacts/<model>/spec.json            layer DAG for the rust graph loader
  artifacts/<model>/full.hlo.txt         whole model, single device
  artifacts/<model>/io/input.bin         golden input  (f32 LE, CHW)
  artifacts/<model>/io/expected.bin      golden output (f32 LE)
  artifacts/<model>/pipeline/plan.json   default pipeline plan (stages,
                                         device splits) for the e2e example
  artifacts/<model>/pipeline/<key>.hlo.txt
                                         per-(layer x tile-shape) stage
                                         executables for that plan
  artifacts/manifest.json                index of everything above

Artifact keys match rust/src/runtime/engine.rs::artifact_key():
  conv/pool:  <layer>__r<in_rows>_pt<pad_top>_pb<pad_bottom>
  dense:      <layer>__full
(add/concat/flatten/split/stitch are executed natively by the rust runtime;
they are data movement, not compute — paper §5.3 does the same in C++.)
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .plan import row_splits, stage_tile_geometry

# Default e2e pipeline plans (stage layer lists + device counts per stage).
# The tinyvgg plan is the 3-stage / 4-device configuration used by
# examples/e2e_serve.rs; stage 1 is feature-split across 2 devices.
DEFAULT_PLANS: dict[str, dict] = {
    "tinyvgg": {
        "stages": [
            {"layers": ["conv1", "conv2", "pool1"], "devices": 2},
            {"layers": ["conv3", "conv4", "pool2"], "devices": 1},
            {"layers": ["conv5", "pool3", "flatten", "fc1", "fc2"], "devices": 1},
        ]
    },
    "tinyresnet": {
        "stages": [
            {"layers": ["stem", "b1_conv1", "b1_conv2", "b1_add"], "devices": 2},
            {
                "layers": [
                    "b2_conv1", "b2_conv2", "b2_proj", "b2_add",
                    "pool", "flatten", "fc",
                ],
                "devices": 1,
            },
        ]
    },
    "tinyinception": {
        "stages": [
            {
                "layers": [
                    "stem", "a_1x1", "b_1x1", "b_3x3", "c_1x7", "c_7x1",
                    "d_pool", "d_1x1", "cat",
                ],
                "devices": 2,
            },
            {"layers": ["tail", "pool", "flatten", "fc"], "devices": 1},
        ]
    },
}

SEED = 0


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: baked weights must survive the text round-trip.
    return comp.as_hlo_text(print_large_constants=True)


def lower_fn(fn, *example_args) -> str:
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def artifact_key(layer: str, in_rows: int, pad_top: int, pad_bottom: int) -> str:
    return f"{layer}__r{in_rows}_pt{pad_top}_pb{pad_bottom}"


def export_full_model(spec: M.ModelSpec, params, outdir: str) -> dict:
    """Whole-model executable + golden io vectors."""
    fn = M.forward_fn(spec, params, impl="pallas")
    x_spec = jax.ShapeDtypeStruct(spec.input_shape, jnp.float32)
    hlo = lower_fn(fn, x_spec)
    full_path = os.path.join(outdir, "full.hlo.txt")
    with open(full_path, "w") as f:
        f.write(hlo)

    rng = np.random.default_rng(42)
    x = rng.standard_normal(spec.input_shape).astype(np.float32)
    y = np.asarray(M.forward(spec, params, jnp.asarray(x), impl="ref"))
    io_dir = os.path.join(outdir, "io")
    os.makedirs(io_dir, exist_ok=True)
    x.tofile(os.path.join(io_dir, "input.bin"))
    y.tofile(os.path.join(io_dir, "expected.bin"))
    return {
        "full": "full.hlo.txt",
        "input": "io/input.bin",
        "expected": "io/expected.bin",
        "input_shape": list(spec.input_shape),
        "output_shape": list(y.shape),
    }


def export_pipeline(spec: M.ModelSpec, params, plan: dict, outdir: str) -> dict:
    """Per-(layer x tile-shape) executables for the default plan."""
    shapes = spec.shapes()
    pipe_dir = os.path.join(outdir, "pipeline")
    os.makedirs(pipe_dir, exist_ok=True)
    artifacts: dict[str, str] = {}
    stages_json = []

    for stage in plan["stages"]:
        layers = stage["layers"]
        ndev = stage["devices"]
        sinks = [
            n
            for n in layers
            if all(c.name not in layers for c in spec.consumers(n))
        ]
        # Row-split every (spatial) sink's output equally across devices.
        splits = {
            s: (
                row_splits(shapes[s][1], ndev)
                if len(shapes[s]) == 3
                else [(0, 1)] * ndev
            )
            for s in sinks
        }
        stages_json.append(
            {
                "layers": layers,
                "devices": ndev,
                "sinks": sinks,
                "splits": {s: [list(iv) for iv in splits[s]] for s in sinks},
            }
        )
        for k in range(ndev):
            sink_out = {s: splits[s][k] for s in sinks}
            tiles = stage_tile_geometry(spec, layers, sink_out)
            for name in layers:
                l = spec.layer(name)
                t = tiles[name]
                if l.op in ("conv", "maxpool", "avgpool"):
                    key = artifact_key(name, t.in_rows, t.pad_top, t.pad_bottom)
                    if key in artifacts:
                        continue
                    c_in, _, w_in = shapes[l.inputs[0]]
                    pad = (t.pad_top, t.pad_bottom, l.padding[1], l.padding[1])

                    def fn(x, l=l, pad=pad):
                        return (M.layer_forward(l, params, [x], "pallas", pad),)

                    x_spec = jax.ShapeDtypeStruct((c_in, t.in_rows, w_in), jnp.float32)
                    hlo = lower_fn(fn, x_spec)
                    fname = f"{key}.hlo.txt"
                    with open(os.path.join(pipe_dir, fname), "w") as f:
                        f.write(hlo)
                    artifacts[key] = f"pipeline/{fname}"
                elif l.op == "dense":
                    key = f"{name}__full"
                    if key in artifacts:
                        continue
                    (f_in,) = shapes[l.inputs[0]]

                    def fn(x, l=l):
                        return (M.layer_forward(l, params, [x], "pallas"),)

                    x_spec = jax.ShapeDtypeStruct((f_in,), jnp.float32)
                    hlo = lower_fn(fn, x_spec)
                    fname = f"{key}.hlo.txt"
                    with open(os.path.join(pipe_dir, fname), "w") as f:
                        f.write(hlo)
                    artifacts[key] = f"pipeline/{fname}"
                # add/concat/flatten: rust-native data movement, no artifact.

    plan_json = {"model": spec.name, "stages": stages_json, "artifacts": artifacts}
    with open(os.path.join(pipe_dir, "plan.json"), "w") as f:
        json.dump(plan_json, f, indent=1)
    return plan_json


def export_model(name: str, outdir: str) -> dict:
    spec = M.E2E_MODELS[name]()
    params = M.init_params(spec, seed=SEED)
    model_dir = os.path.join(outdir, name)
    os.makedirs(model_dir, exist_ok=True)
    spec.save(os.path.join(model_dir, "spec.json"))
    entry = {"spec": "spec.json"}
    entry.update(export_full_model(spec, params, model_dir))
    plan_json = export_pipeline(spec, params, DEFAULT_PLANS[name], model_dir)
    entry["plan"] = "pipeline/plan.json"
    entry["pipeline_artifacts"] = len(plan_json["artifacts"])
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument(
        "--models",
        default=",".join(M.E2E_MODELS),
        help="comma-separated subset of models to export",
    )
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    manifest: dict = {"seed": SEED, "models": {}}
    for name in args.models.split(","):
        name = name.strip()
        print(f"[aot] exporting {name} ...", flush=True)
        manifest["models"][name] = export_model(name, outdir)
        print(f"[aot] {name}: {manifest['models'][name]['pipeline_artifacts']} pipeline artifacts")
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {os.path.join(outdir, 'manifest.json')}")


if __name__ == "__main__":
    main()
