"""L2 geometry + stage-execution tests: the python side of the
python↔rust tile contract (rust/tests/integration.rs pins the same
golden values)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.plan import (
    required_rows,
    row_splits,
    run_stage_tile,
    stage_tile_geometry,
)

RNG = np.random.default_rng(7)


def rand_input(spec):
    return jnp.asarray(RNG.standard_normal(spec.input_shape), jnp.float32)


# ------------------------------------------------------ required_rows


def test_required_rows_conv3x3():
    spec = M.tiny_vgg()
    conv1 = spec.layer("conv1")  # 3x3 s1 p1
    assert required_rows(conv1, (0, 16)) == (-1, 17)
    assert required_rows(conv1, (5, 9)) == (4, 10)


def test_required_rows_pool():
    spec = M.tiny_vgg()
    pool = spec.layer("pool1")  # 2x2 s2 p0
    assert required_rows(pool, (0, 8)) == (0, 16)
    assert required_rows(pool, (4, 8)) == (8, 16)


def test_required_rows_unbalanced_kernels():
    spec = M.tiny_inception()
    c17 = spec.layer("c_1x7")  # kh=1: no row halo
    assert required_rows(c17, (3, 7)) == (3, 7)
    c71 = spec.layer("c_7x1")  # kh=7 p3
    assert required_rows(c71, (3, 7)) == (0, 10)


# ----------------------------------------------- golden tile geometry


def test_golden_tinyvgg_stage1():
    """Must match rust cost::feature golden tests and the artifact keys
    (conv1__r18_pt1_pb0 etc.)."""
    spec = M.tiny_vgg()
    layers = ["conv1", "conv2", "pool1"]
    t = stage_tile_geometry(spec, layers, {"pool1": (0, 8)})
    assert (t["conv2"].in_rows, t["conv2"].pad_top, t["conv2"].pad_bottom) == (17, 1, 0)
    assert (t["conv1"].in_rows, t["conv1"].pad_top, t["conv1"].pad_bottom) == (18, 1, 0)
    assert t["input"].out_iv == (0, 18)

    t = stage_tile_geometry(spec, layers, {"pool1": (8, 16)})
    assert (t["conv2"].in_rows, t["conv2"].pad_top, t["conv2"].pad_bottom) == (17, 0, 1)
    assert (t["conv1"].in_rows, t["conv1"].pad_top, t["conv1"].pad_bottom) == (18, 0, 1)
    assert t["input"].out_iv == (14, 32)


def test_row_splits():
    assert row_splits(32, 2) == [(0, 16), (16, 32)]
    assert row_splits(7, 3) == [(0, 3), (3, 5), (5, 7)]
    with pytest.raises(AssertionError):
        row_splits(3, 4)


# ------------------------------------- split-equals-whole (per model)


def pipeline_outputs(spec, stages, devices_per_stage, impl="ref"):
    """Drive the staged execution exactly like the rust coordinator."""
    params = M.init_params(spec)
    x = rand_input(spec)
    shapes = spec.shapes()
    avail = {"input": x}
    for layers, ndv in zip(stages, devices_per_stage):
        sinks = [
            n for n in layers if all(c.name not in layers for c in spec.consumers(n))
        ]
        splits = {
            s: (row_splits(shapes[s][1], ndv) if len(shapes[s]) == 3 else [(0, 1)] * ndv)
            for s in sinks
        }
        parts = {s: [] for s in sinks}
        for k in range(ndv):
            tiles = stage_tile_geometry(spec, layers, {s: splits[s][k] for s in sinks})
            feeds = {}
            for name, t in tiles.items():
                if name not in layers or name == "input":
                    src = avail[name]
                    feeds[name] = (
                        src[:, t.out_iv[0] : t.out_iv[1], :] if src.ndim == 3 else src
                    )
            res = run_stage_tile(spec, params, layers, tiles, feeds, impl=impl)
            for s in sinks:
                parts[s].append(res[s])
        for s in sinks:
            avail[s] = (
                jnp.concatenate(parts[s], axis=1) if len(shapes[s]) == 3 else parts[s][0]
            )
    want = M.forward(spec, params, x, impl="ref")
    got = avail[stages[-1][-1]]
    return got, want


TINY_STAGE_PLANS = {
    "tinyvgg": (
        [["conv1", "conv2", "pool1"], ["conv3", "conv4", "pool2"],
         ["conv5", "pool3", "flatten", "fc1", "fc2"]],
        [2, 2, 1],
    ),
    "tinyresnet": (
        [["stem", "b1_conv1", "b1_conv2", "b1_add"],
         ["b2_conv1", "b2_conv2", "b2_proj", "b2_add", "pool", "flatten", "fc"]],
        [3, 1],
    ),
    "tinyinception": (
        [["stem", "a_1x1", "b_1x1", "b_3x3", "c_1x7", "c_7x1", "d_pool", "d_1x1", "cat"],
         ["tail", "pool", "flatten", "fc"]],
        [2, 1],
    ),
}


@pytest.mark.parametrize("name", list(TINY_STAGE_PLANS))
def test_staged_equals_whole(name):
    spec = M.E2E_MODELS[name]()
    stages, ndv = TINY_STAGE_PLANS[name]
    got, want = pipeline_outputs(spec, stages, ndv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


@settings(deadline=None, max_examples=10)
@given(d1=st.integers(1, 4), d2=st.integers(1, 4))
def test_staged_equals_whole_hypothesis_splits(d1, d2):
    spec = M.tiny_vgg()
    stages = [["conv1", "conv2", "pool1"], ["conv3", "conv4", "pool2"],
              ["conv5", "pool3", "flatten", "fc1", "fc2"]]
    got, want = pipeline_outputs(spec, stages, [d1, d2, 1])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------- model structure


def test_shapes_match_expected():
    spec = M.tiny_vgg()
    s = spec.shapes()
    assert s["pool1"] == (16, 16, 16)
    assert s["pool3"] == (64, 4, 4)
    assert s["fc2"] == (10,)
    inc = M.tiny_inception()
    si = inc.shapes()
    assert si["cat"] == (32, 16, 16)


def test_forward_pallas_matches_ref():
    for name, build in M.E2E_MODELS.items():
        spec = build()
        params = M.init_params(spec)
        x = rand_input(spec)
        got = M.forward(spec, params, x, impl="pallas")
        want = M.forward(spec, params, x, impl="ref")
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4,
            err_msg=name,
        )


def test_spec_json_roundtrip(tmp_path):
    spec = M.tiny_resnet()
    p = tmp_path / "spec.json"
    spec.save(str(p))
    import json

    loaded = json.loads(p.read_text())
    assert loaded["name"] == "tinyresnet"
    assert [l["name"] for l in loaded["layers"]][0] == "input"
    assert loaded["input_shape"] == [3, 32, 32]
