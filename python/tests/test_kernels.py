"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Deterministic cases cover every configuration the e2e models use (including
the unbalanced 1x7 / 7x1 Inception kernels that motivate the paper's graph
partition, Fig. 6); hypothesis sweeps randomise shapes, strides, padding and
activations. All kernels run interpret=True (CPU).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.conv2d import conv2d, vmem_bytes
from compile.kernels.matmul import dense
from compile.kernels.pool import avgpool2d, maxpool2d

RNG = np.random.default_rng(1234)


def rand(shape):
    return jnp.asarray(RNG.standard_normal(shape), jnp.float32)


def assert_close(got, want, rtol=1e-4, atol=1e-5):
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=rtol, atol=atol)


# ---------------------------------------------------------------- conv2d

CONV_CASES = [
    # (C_in, H, W, C_out, (kh, kw), (sh, sw), (ph, pw), act)
    (3, 32, 32, 16, (3, 3), (1, 1), (1, 1), "relu"),      # VGG-style body
    (16, 16, 16, 32, (3, 3), (2, 2), (1, 1), "relu"),     # strided reduce
    (8, 14, 14, 8, (1, 1), (1, 1), (0, 0), "linear"),     # pointwise
    (4, 12, 12, 6, (5, 5), (1, 1), (2, 2), "relu"),       # 5x5 inception tap
    (4, 12, 12, 6, (1, 7), (1, 1), (0, 3), "relu"),       # unbalanced, Fig. 6
    (4, 12, 12, 6, (7, 1), (1, 1), (3, 0), "relu"),       # unbalanced, Fig. 6
    (3, 20, 20, 8, (3, 3), (1, 1), (0, 0), "leaky"),      # YOLO activation
    (3, 11, 13, 5, (3, 3), (2, 2), (1, 1), "relu"),       # odd dims
    (2, 7, 7, 3, (7, 7), (1, 1), (0, 0), "linear"),       # window == input
]


@pytest.mark.parametrize("ci,h,w,co,k,s,p,act", CONV_CASES)
def test_conv2d_matches_ref(ci, h, w, co, k, s, p, act):
    x = rand((ci, h, w))
    wt = rand((co, ci, *k))
    b = rand((co,))
    got = conv2d(x, wt, b, stride=s, padding=p, activation=act)
    want = ref.conv2d(x, wt, b, stride=s, padding=p, activation=act)
    assert_close(got, want)


def test_conv2d_no_bias():
    x = rand((3, 8, 8))
    wt = rand((4, 3, 3, 3))
    assert_close(conv2d(x, wt), ref.conv2d(x, wt))


def test_conv2d_explicit_row_tile():
    x = rand((3, 12, 12))
    wt = rand((4, 3, 3, 3))
    b = rand((4,))
    want = ref.conv2d(x, wt, b, padding=(1, 1))
    for th in (1, 2, 3, 4, 6, 12):
        got = conv2d(x, wt, b, padding=(1, 1), row_tile=th)
        assert_close(got, want)


def test_conv2d_channel_mismatch_raises():
    with pytest.raises(AssertionError):
        conv2d(rand((3, 8, 8)), rand((4, 2, 3, 3)))


def test_conv2d_bad_row_tile_raises():
    with pytest.raises(AssertionError):
        conv2d(rand((3, 8, 8)), rand((4, 3, 3, 3)), row_tile=5)


def test_vmem_bytes_monotone_in_tile():
    small = vmem_bytes(64, 128, 32, 34, 32, (3, 3), (1, 1), row_tile=2)
    large = vmem_bytes(64, 128, 32, 34, 32, (3, 3), (1, 1), row_tile=16)
    assert small < large
    # weights-only lower bound
    assert small > 4 * 64 * 128 * 9


@settings(deadline=None, max_examples=30)
@given(
    ci=st.integers(1, 6),
    co=st.integers(1, 8),
    h=st.integers(3, 18),
    w=st.integers(3, 18),
    k=st.sampled_from([(1, 1), (3, 3), (5, 5), (1, 3), (3, 1)]),
    s=st.sampled_from([(1, 1), (2, 2), (1, 2)]),
    p=st.sampled_from([(0, 0), (1, 1), (2, 0)]),
    act=st.sampled_from(["linear", "relu", "leaky"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_hypothesis(ci, co, h, w, k, s, p, act, seed):
    kh, kw = k
    if h + 2 * p[0] < kh or w + 2 * p[1] < kw:
        return  # window larger than padded input: rejected by kernel assert
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((ci, h, w)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((co, ci, kh, kw)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((co,)), jnp.float32)
    got = conv2d(x, wt, b, stride=s, padding=p, activation=act)
    want = ref.conv2d(x, wt, b, stride=s, padding=p, activation=act)
    assert_close(got, want)


# ---------------------------------------------------------------- pooling

POOL_CASES = [
    ((2, 2), None, (0, 0)),
    ((2, 2), (2, 2), (0, 0)),
    ((3, 3), (2, 2), (1, 1)),
    ((3, 2), (1, 2), (0, 1)),
    ((2, 2), (1, 1), (0, 0)),
]


@pytest.mark.parametrize("k,s,p", POOL_CASES)
def test_maxpool_matches_ref(k, s, p):
    x = rand((5, 14, 11))
    assert_close(maxpool2d(x, k, s, p), ref.maxpool2d(x, k, s, p))


@pytest.mark.parametrize("k,s,p", POOL_CASES)
def test_avgpool_matches_ref(k, s, p):
    x = rand((5, 14, 11))
    assert_close(avgpool2d(x, k, s, p), ref.avgpool2d(x, k, s, p), atol=1e-6)


def test_maxpool_padding_uses_neg_inf():
    # All-negative input: zero padding would corrupt the max at the border.
    x = -jnp.ones((1, 4, 4), jnp.float32) * 7.0
    got = maxpool2d(x, (3, 3), (1, 1), (1, 1))
    assert np.all(np.asarray(got) == -7.0)


@settings(deadline=None, max_examples=20)
@given(
    c=st.integers(1, 6),
    h=st.integers(4, 16),
    w=st.integers(4, 16),
    k=st.sampled_from([(2, 2), (3, 3), (3, 2)]),
    s=st.sampled_from([None, (1, 1), (2, 2)]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pool_hypothesis(c, h, w, k, s, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((c, h, w)), jnp.float32)
    assert_close(maxpool2d(x, k, s), ref.maxpool2d(x, k, s))
    assert_close(avgpool2d(x, k, s), ref.avgpool2d(x, k, s), atol=1e-6)


# ---------------------------------------------------------------- dense

@pytest.mark.parametrize("o,f,act", [(10, 48, "linear"), (16, 64, "relu"), (7, 33, "leaky")])
def test_dense_matches_ref(o, f, act):
    x = rand((f,))
    w = rand((o, f))
    b = rand((o,))
    assert_close(dense(x, w, b, act), ref.dense(x, w, b, act))


def test_dense_no_bias():
    x = rand((20,))
    w = rand((5, 20))
    assert_close(dense(x, w), ref.dense(x, w))


def test_dense_row_tiles_agree():
    x = rand((24,))
    w = rand((12, 24))
    b = rand((12,))
    want = ref.dense(x, w, b)
    for t in (1, 2, 3, 4, 6, 12):
        assert_close(dense(x, w, b, row_tile=t), want)


@settings(deadline=None, max_examples=20)
@given(o=st.integers(1, 32), f=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
def test_dense_hypothesis(o, f, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((f,)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((o, f)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((o,)), jnp.float32)
    assert_close(dense(x, w, b, "relu"), ref.dense(x, w, b, "relu"))


# ------------------------------------------------- halo-tiling invariant

def test_conv_tile_halo_equivalence():
    """The paper's core overlap identity (Eq. 3): computing a conv on a
    row-slice of the input with the proper halo reproduces the matching
    row-slice of the full output. This is exactly what a PICO stage does
    across devices; here we check the kernel supports it numerically."""
    x = rand((3, 24, 24))
    wt = rand((8, 3, 3, 3))
    b = rand((8,))
    full = ref.conv2d(x, wt, b, stride=(1, 1), padding=(0, 0), activation="relu")
    h_out = full.shape[1]  # 22
    # device 1 gets output rows [0, 11), device 2 rows [11, 22)
    split = 11
    kh, sh = 3, 1
    # required input rows per Eq. (3): (rows-1)*s + k
    x1 = x[:, 0 : (split - 1) * sh + kh, :]
    x2 = x[:, split * sh : split * sh + (h_out - split - 1) * sh + kh, :]
    y1 = conv2d(x1, wt, b, activation="relu")
    y2 = conv2d(x2, wt, b, activation="relu")
    assert_close(jnp.concatenate([y1, y2], axis=1), full)
