"""AOT artifact validation: the exported artifact set must exactly cover
the tile shapes the default plans need (python side of the contract that
rust/tests/integration.rs checks from the rust side).

These tests validate the artifacts/ directory produced by `make
artifacts`; they skip when it does not exist (pure-kernel CI runs).
"""

import json
import os
import pathlib

import numpy as np
import pytest

from compile import model as M
from compile.aot import DEFAULT_PLANS, artifact_key
from compile.plan import row_splits, stage_tile_geometry

ARTIFACTS = pathlib.Path(__file__).resolve().parents[2] / "artifacts"

pytestmark = pytest.mark.skipif(
    not (ARTIFACTS / "manifest.json").exists(), reason="run `make artifacts` first"
)


def load_manifest():
    return json.loads((ARTIFACTS / "manifest.json").read_text())


def test_manifest_lists_all_models():
    m = load_manifest()
    assert set(m["models"]) == set(M.E2E_MODELS)
    for name, entry in m["models"].items():
        for key in ["spec", "full", "input", "expected", "plan"]:
            assert (ARTIFACTS / name / entry[key]).exists(), f"{name}:{key}"


@pytest.mark.parametrize("name", list(M.E2E_MODELS))
def test_spec_matches_builder(name):
    spec_file = json.loads((ARTIFACTS / name / "spec.json").read_text())
    spec = M.E2E_MODELS[name]()
    assert spec_file["name"] == spec.name
    assert [l["name"] for l in spec_file["layers"]] == [l.name for l in spec.layers]
    assert tuple(spec_file["input_shape"]) == spec.input_shape


@pytest.mark.parametrize("name", list(M.E2E_MODELS))
def test_plan_artifacts_cover_required_tiles(name):
    spec = M.E2E_MODELS[name]()
    shapes = spec.shapes()
    plan = json.loads((ARTIFACTS / name / "pipeline" / "plan.json").read_text())
    artifacts = plan["artifacts"]
    for file in artifacts.values():
        assert (ARTIFACTS / name / file).exists()
    # Recompute the geometry; every spatial layer tile must have a key.
    for stage in DEFAULT_PLANS[name]["stages"]:
        layers = stage["layers"]
        ndev = stage["devices"]
        sinks = [
            n for n in layers if all(c.name not in layers for c in spec.consumers(n))
        ]
        for k in range(ndev):
            sink_out = {}
            for s in sinks:
                if len(shapes[s]) == 3:
                    sink_out[s] = row_splits(shapes[s][1], ndev)[k]
                else:
                    sink_out[s] = (0, 1)
            tiles = stage_tile_geometry(spec, layers, sink_out)
            for n in layers:
                l = spec.layer(n)
                if l.op in ("conv", "maxpool", "avgpool"):
                    key = artifact_key(n, tiles[n].in_rows, tiles[n].pad_top, tiles[n].pad_bottom)
                    assert key in artifacts, f"{name}: missing {key}"
                elif l.op == "dense":
                    assert f"{n}__full" in artifacts, f"{name}: missing {n}__full"


@pytest.mark.parametrize("name", list(M.E2E_MODELS))
def test_golden_io_shapes(name):
    spec = M.E2E_MODELS[name]()
    c, h, w = spec.input_shape
    x = np.fromfile(ARTIFACTS / name / "io" / "input.bin", dtype=np.float32)
    assert x.size == c * h * w
    y = np.fromfile(ARTIFACTS / name / "io" / "expected.bin", dtype=np.float32)
    out_shape = spec.shapes()[spec.layers[-1].name]
    assert y.size == int(np.prod(out_shape))
    # Golden output must match a fresh ref-forward with the same seed.
    params = M.init_params(spec, seed=0)
    import jax.numpy as jnp

    got = M.forward(spec, params, jnp.asarray(x.reshape(c, h, w)), impl="ref")
    np.testing.assert_allclose(np.asarray(got).ravel(), y, rtol=1e-5, atol=1e-6)


def test_hlo_text_has_constants_not_elided():
    # Weight baking: the exported HLO must carry real constant payloads
    # ("{...}" means as_hlo_text dropped them and the rust runtime would
    # compute garbage).
    for name in M.E2E_MODELS:
        full = (ARTIFACTS / name / "full.hlo.txt").read_text()
        assert "{...}" not in full, f"{name}: elided constants"
        assert "HloModule" in full
