//! Minimal vendored stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so this workspace
//! vendors the tiny subset of `anyhow` the codebase actually uses:
//! [`Result`], [`Error`], [`anyhow!`], [`bail!`] and [`ensure!`], plus
//! the blanket `From<E: std::error::Error>` conversion that makes `?`
//! work on std errors (io, parse, channel) inside `anyhow::Result`
//! functions. Dropping the real `anyhow` crate back in is a one-line
//! change in `rust/Cargo.toml`; nothing here extends its semantics.

use std::fmt;

/// A type-erased error: a message built eagerly from the source error's
/// chain. `{}` and `{:#}` both print the full chain (the real anyhow
/// prints the chain only under `{:#}`; callers here only ever format
/// errors for humans, so the distinction is not load-bearing).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// The same coherence trick the real anyhow uses: `Error` itself does not
// implement `std::error::Error`, so this blanket impl is allowed and
// gives `?` conversions from any std error type.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error { msg }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    #[test]
    fn macros_build_errors() {
        fn inner(x: usize) -> crate::Result<usize> {
            crate::ensure!(x > 1, "x too small: {x}");
            if x > 10 {
                crate::bail!("x too large: {}", x);
            }
            Ok(x)
        }
        assert_eq!(inner(5).unwrap(), 5);
        assert_eq!(format!("{}", inner(0).err().unwrap()), "x too small: 0");
        assert_eq!(format!("{:#}", inner(11).err().unwrap()), "x too large: 11");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> crate::Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(format!("{}", parse("x").err().unwrap()).contains("invalid digit"));
    }
}
