//! Vendored stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The build environment has no registry access and no libxla, so this
//! crate mirrors the exact API surface `pico::runtime::engine` consumes
//! and makes every fallible entry point return [`Error`] stating that
//! the PJRT backend is unavailable. The serving stack degrades
//! gracefully: `Engine::cpu()` fails, callers fall back to the native
//! backend, and artifact-dependent tests skip. To enable real AOT
//! execution, replace the `xla = { path = "../vendor/xla" }` dependency
//! in `rust/Cargo.toml` with the real xla-rs crate — no source changes.

use std::fmt;

const UNAVAILABLE: &str =
    "PJRT backend unavailable: built against the vendored xla stub (swap in the real xla-rs \
     crate in rust/Cargo.toml to execute AOT artifacts)";

/// Stub error carrying the unavailability message.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    fn unavailable() -> Error {
        Error(UNAVAILABLE.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-side literal (stub: holds nothing).
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::unavailable())
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(Error::unavailable())
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable())
    }
}

/// Array shape metadata (stub: always empty).
pub struct ArrayShape;

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &[]
    }
}

/// Device-side buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable())
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable())
    }
}

/// PJRT client (stub: construction fails, so callers fall back).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable())
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable())
    }
}

/// Parsed HLO module (stub: parsing fails).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::unavailable())
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}
